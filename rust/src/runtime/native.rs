//! Native backend: pure-Rust artifacts over the [`crate::kernels`]
//! subsystem — training and inference run end-to-end with **no**
//! `artifacts/` directory, no Python, and no XLA shared library.
//!
//! Two artifact families are synthesized on demand:
//!
//! * **Micro kernels** — `micro_dense_n{N}`, `micro_diag_n{N}_k{K}`,
//!   `micro_bcsr_n{N}_nnzb{Z}_bs{BS}`: single-op artifacts with the exact IO
//!   contract of their Pallas-lowered counterparts (Fig 7 / Table 8
//!   benches, kernel parity tests).
//! * **MLP models** — `mlp_micro` / `mlp_tiny`, a pooled-patch MLP
//!   classifier whose sparse layers (`blocks/{b}/fc1`, `blocks/{b}/fc2`)
//!   support the same three parameterizations as the L2 zoo: `masked`
//!   (`W_eff = W ⊙ M`), `dynadiag` (Eq. 4–5: `W_eff = V ⊙ ᾱ[(j−i) mod
//!   n_in]`, soft-TopK over trained α), and diagonal-selected inference
//!   (`{model}_diag_infer{S}` over offsets+values through the diag SpMM
//!   kernel). Train steps run forward + hand-written backprop + in-step
//!   AdamW, mirroring `python/compile/{model,optim}.py`; the IO contract
//!   (section prefixes, flatten order, output routing) is identical, so
//!   `train::Trainer` drives both backends with the same code.
//!
//! **Hot-path memory model.** Every tensor-sized buffer in the step
//! functions — activations, gradients, optimizer scratch, converted index
//! buffers, and the output tensors themselves — is drawn from the
//! [`workspace`] arena, a thread-local pool of recycled buffers. Step
//! functions return their intermediates at the end of each call, and
//! callers that recycle the step outputs (`train::Trainer` does, via
//! `ParamStore::absorb_take`) close the loop: in steady state a train step
//! performs **zero** buffer allocations. Per-step IO routing is resolved
//! once at artifact-build time into index *plans* (no per-step name
//! formatting or map lookups). The diag products inside the step functions
//! run on the process-wide dispatched SIMD path
//! ([`crate::kernels::microkernel`], `DYNADIAG_ISA` override); dispatch
//! resolves lazily on the first kernel call and allocates a little
//! (env read), which is one-time init, not steady-state — the
//! `native_steady_state.rs` gates resolve it before opening their measured
//! windows.
//!
//! The transformer models (`vit_*`, `mixer_*`, `gpt_*`) remain
//! XLA-artifact-only; asking for them here produces a clear error.
//!
//! One deliberate approximation: the α gradient treats the soft-TopK
//! normalizer exactly (softmax Jacobian with saturation masking,
//! `min(k·softmax(α/T), 1)`) but uses the subgradient 0 at the `min`
//! boundary, like XLA's autodiff of `min` on ties.

use anyhow::{anyhow, bail, Result};

use super::{Artifact, ArtifactMeta, Backend, Dtype, HostTensor, IoSpec, StepFn};
use crate::kernels::{bcsr, dense, diag, gelu, gelu_prime, pool};
use crate::sparsity::topk::soft_topk;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Workspace arena
// ---------------------------------------------------------------------------

/// Thread-local recycled-buffer arena behind the native hot path.
///
/// `take_*` hands out a buffer of the requested length, reusing a pooled
/// one when any fits (best-fit by capacity) and allocating fresh otherwise;
/// `give_*` returns a buffer to the pool. [`stats`] exposes
/// `(fresh, reused)` counters so tests can assert the steady state: after
/// warmup, a training loop that recycles its outputs performs zero fresh
/// allocations (the `fresh` counter stops moving).
///
/// The pools are thread-local (the native backend is single-threaded per
/// session; kernel worker threads receive plain slices and never touch the
/// arena), so there is no locking and the counters are deterministic.
pub mod workspace {
    use super::HostTensor;
    use std::cell::RefCell;

    /// Retention cap per pool — bounds worst-case memory held by the arena.
    const MAX_POOLED: usize = 1024;

    #[derive(Default)]
    struct Pools {
        f32s: Vec<Vec<f32>>,
        i32s: Vec<Vec<i32>>,
        usizes: Vec<Vec<usize>>,
        fresh: usize,
        reused: usize,
    }

    thread_local! {
        static POOLS: RefCell<Pools> = RefCell::new(Pools::default());
    }

    /// (fresh allocations, pool reuses) on this thread since the last
    /// [`reset_stats`].
    pub fn stats() -> (usize, usize) {
        POOLS.with(|p| {
            let p = p.borrow();
            (p.fresh, p.reused)
        })
    }

    /// Fresh-allocation count alone (the steady-state invariant).
    pub fn fresh_allocs() -> usize {
        stats().0
    }

    pub fn reset_stats() {
        POOLS.with(|p| {
            let mut p = p.borrow_mut();
            p.fresh = 0;
            p.reused = 0;
        })
    }

    /// Best-fit index: smallest pooled buffer whose capacity covers `len`.
    fn best_fit_by_cap(caps: impl Iterator<Item = usize>, len: usize) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (i, cap) in caps.enumerate() {
            if cap >= len && best.map_or(true, |(_, c)| cap < c) {
                best = Some((i, cap));
            }
        }
        best.map(|(i, _)| i)
    }

    macro_rules! pool_impl {
        ($take:ident, $take_uninit:ident, $take_copy:ident, $give:ident, $field:ident, $t:ty, $zero:expr) => {
            /// Take a zero-initialized buffer of exactly `len` elements.
            pub fn $take(len: usize) -> Vec<$t> {
                POOLS.with(|p| {
                    let mut p = p.borrow_mut();
                    let fit = best_fit_by_cap(p.$field.iter().map(|b| b.capacity()), len);
                    match fit {
                        Some(i) => {
                            p.reused += 1;
                            let mut v = p.$field.swap_remove(i);
                            v.clear();
                            v.resize(len, $zero);
                            v
                        }
                        None => {
                            p.fresh += 1;
                            vec![$zero; len]
                        }
                    }
                })
            }

            /// Take a buffer of exactly `len` elements with **unspecified
            /// contents** (stale values from a previous use; no memset when
            /// a same-length buffer is pooled). Only for consumers that
            /// fully overwrite the buffer before reading it — kernel
            /// outputs that `fill(0.0)` internally, element-wise maps, etc.
            pub fn $take_uninit(len: usize) -> Vec<$t> {
                POOLS.with(|p| {
                    let mut p = p.borrow_mut();
                    let fit = best_fit_by_cap(p.$field.iter().map(|b| b.capacity()), len);
                    match fit {
                        Some(i) => {
                            p.reused += 1;
                            let mut v = p.$field.swap_remove(i);
                            if v.len() != len {
                                v.clear();
                                v.resize(len, $zero);
                            }
                            v
                        }
                        None => {
                            p.fresh += 1;
                            vec![$zero; len]
                        }
                    }
                })
            }

            /// Take a buffer holding a copy of `src`.
            pub fn $take_copy(src: &[$t]) -> Vec<$t> {
                POOLS.with(|p| {
                    let mut p = p.borrow_mut();
                    let fit =
                        best_fit_by_cap(p.$field.iter().map(|b| b.capacity()), src.len());
                    match fit {
                        Some(i) => {
                            p.reused += 1;
                            let mut v = p.$field.swap_remove(i);
                            v.clear();
                            v.extend_from_slice(src);
                            v
                        }
                        None => {
                            p.fresh += 1;
                            src.to_vec()
                        }
                    }
                })
            }

            /// Return a buffer to the pool (empty buffers are dropped; the
            /// pool is capped at `MAX_POOLED` entries).
            pub fn $give(v: Vec<$t>) {
                if v.capacity() == 0 {
                    return;
                }
                POOLS.with(|p| {
                    let mut p = p.borrow_mut();
                    if p.$field.len() < MAX_POOLED {
                        p.$field.push(v);
                    }
                })
            }
        };
    }

    pool_impl!(take_f32, take_uninit_f32, take_copy_f32, give_f32, f32s, f32, 0.0f32);
    pool_impl!(take_i32, take_uninit_i32, take_copy_i32, give_i32, i32s, i32, 0i32);
    pool_impl!(take_usize, take_uninit_usize, take_copy_usize, give_usize, usizes, usize, 0usize);

    /// Build an f32 tensor around a workspace buffer (pooled shape vec).
    pub fn tensor_f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape: take_copy_usize(shape), data }
    }

    /// Build an i32 tensor around a workspace buffer (pooled shape vec).
    pub fn tensor_i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { shape: take_copy_usize(shape), data }
    }

    /// Scalar (shape `[]`) f32 tensor from the pool. The empty shape vec
    /// never allocates.
    pub fn tensor_scalar(v: f32) -> HostTensor {
        let mut data = take_uninit_f32(1);
        data[0] = v;
        HostTensor::F32 { shape: Vec::new(), data }
    }

    /// Pool-backed deep copy of a tensor.
    pub fn clone_tensor(t: &HostTensor) -> HostTensor {
        match t {
            HostTensor::F32 { shape, data } => HostTensor::F32 {
                shape: take_copy_usize(shape),
                data: take_copy_f32(data),
            },
            HostTensor::I32 { shape, data } => HostTensor::I32 {
                shape: take_copy_usize(shape),
                data: take_copy_i32(data),
            },
        }
    }

    /// Recycle a tensor's buffers back into the pool.
    pub fn give_tensor(t: HostTensor) {
        match t {
            HostTensor::F32 { shape, data } => {
                give_usize(shape);
                give_f32(data);
            }
            HostTensor::I32 { shape, data } => {
                give_usize(shape);
                give_i32(data);
            }
        }
    }
}

/// Test/bench support: synthesize inputs for a native train artifact and
/// drive the workspace-recycled feedback loop the way `Trainer` does.
/// Shared by `benches/kernels.rs` and `tests/native_steady_state.rs` so
/// both exercise the identical loop; not a stability surface.
#[doc(hidden)]
pub mod drive {
    use super::workspace;
    use super::{Artifact, HostTensor};
    use crate::util::rng::Rng;

    /// Deterministic synthetic inputs for a train artifact: params ~
    /// N(0, 0.05), all-ones masks, a random batch, lr 1e-3, step 1,
    /// zeros for everything else.
    pub fn synth_train_inputs(art: &Artifact, seed: u64) -> Vec<HostTensor> {
        let classes = art.meta.config_usize("classes").unwrap_or(10);
        let mut rng = Rng::new(seed);
        let mut inputs = Vec::with_capacity(art.meta.inputs.len());
        for spec in &art.meta.inputs {
            let n: usize = spec.shape.iter().product();
            let t = if spec.name.starts_with("params/") {
                HostTensor::f32(
                    &spec.shape,
                    (0..n).map(|_| rng.normal_f32(0.0, 0.05)).collect(),
                )
            } else if spec.name.starts_with("masks/") {
                HostTensor::f32(&spec.shape, vec![1.0; n])
            } else if spec.name == "batch/x" {
                HostTensor::f32(
                    &spec.shape,
                    (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
                )
            } else if spec.name == "batch/y" {
                HostTensor::i32(
                    &spec.shape,
                    (0..n).map(|_| rng.below(classes) as i32).collect(),
                )
            } else if spec.name == "scalar/lr" {
                HostTensor::scalar_f32(1e-3)
            } else if spec.name == "scalar/step" {
                HostTensor::scalar_f32(1.0)
            } else {
                HostTensor::zeros(spec)
            };
            inputs.push(t);
        }
        inputs
    }

    /// Output→input feedback routing for the recycled train loop (the
    /// absorb contract: every `params/`/`opt_*` input reappears as an
    /// output under the same name).
    pub struct TrainFeedback {
        route: Vec<Option<usize>>,
        step_slot: Option<usize>,
        step_no: f32,
    }

    impl TrainFeedback {
        pub fn new(art: &Artifact) -> TrainFeedback {
            let route = art
                .meta
                .inputs
                .iter()
                .map(|spec| {
                    if spec.name.starts_with("params/")
                        || spec.name.starts_with("opt_m/")
                        || spec.name.starts_with("opt_v/")
                    {
                        Some(art.meta.output_index(&spec.name).expect("absorb contract"))
                    } else {
                        None
                    }
                })
                .collect();
            let step_slot = art.meta.inputs.iter().position(|s| s.name == "scalar/step");
            TrainFeedback { route, step_slot, step_no: 1.0 }
        }

        /// Move params/opt outputs back into `inputs`, bump `scalar/step`,
        /// and recycle every superseded/remaining buffer.
        pub fn apply(&mut self, inputs: &mut [HostTensor], mut outputs: Vec<HostTensor>) {
            for (i, slot) in self.route.iter().enumerate() {
                if let Some(oi) = *slot {
                    let t = std::mem::replace(
                        &mut outputs[oi],
                        HostTensor::F32 { shape: Vec::new(), data: Vec::new() },
                    );
                    let old = std::mem::replace(&mut inputs[i], t);
                    workspace::give_tensor(old);
                }
            }
            if let Some(si) = self.step_slot {
                self.step_no += 1.0;
                let old = std::mem::replace(
                    &mut inputs[si],
                    workspace::tensor_scalar(self.step_no),
                );
                workspace::give_tensor(old);
            }
            for t in outputs.drain(..) {
                workspace::give_tensor(t);
            }
        }
    }
}

/// The artifact-free backend.
pub struct NativeBackend;

impl NativeBackend {
    #[allow(clippy::new_without_default)]
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn load(&self, name: &str) -> Result<Artifact> {
        if let Some(art) = micro_artifact(name)? {
            return Ok(art);
        }
        for cfg in MODELS {
            let Some(rest) = name.strip_prefix(cfg.name).and_then(|r| r.strip_prefix('_'))
            else {
                continue;
            };
            return match rest {
                "masked_train" => Ok(train_artifact(cfg, Param::Masked)),
                "dynadiag_train" => Ok(train_artifact(cfg, Param::DynaDiag)),
                "masked_eval" => Ok(eval_artifact(cfg, Param::Masked)),
                "dynadiag_eval" => Ok(eval_artifact(cfg, Param::DynaDiag)),
                "masked_gradprobe" => Ok(gradprobe_artifact(cfg)),
                r => {
                    if let Some(pct) = r.strip_prefix("diag_infer") {
                        let pct: f64 = pct
                            .parse::<u32>()
                            .map_err(|_| anyhow!("bad diag_infer sparsity in '{}'", name))?
                            as f64;
                        Ok(diag_infer_artifact(cfg, pct / 100.0))
                    } else {
                        bail!("model '{}' has no native artifact kind '{}'", cfg.name, r)
                    }
                }
            };
        }
        bail!(
            "artifact '{}' is not available on the native backend (native models: \
             mlp_micro, mlp_tiny; micro_dense/micro_diag/micro_bcsr kernels are \
             synthesized on demand). For vit/mixer/gpt models run `make artifacts` \
             and use the xla backend",
            name
        )
    }

    fn artifact_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for cfg in MODELS {
            for kind in [
                "masked_train",
                "dynadiag_train",
                "masked_gradprobe",
                "masked_eval",
                "dynadiag_eval",
                "diag_infer90",
            ] {
                out.push(format!("{}_{}", cfg.name, kind));
            }
        }
        out.push("micro_dense_n<N>".to_string());
        out.push("micro_diag_n<N>_k<K>".to_string());
        out.push("micro_bcsr_n<N>_nnzb<Z>_bs<BS>".to_string());
        out
    }
}

// ---------------------------------------------------------------------------
// Micro kernel artifacts
// ---------------------------------------------------------------------------

/// Batch size of every micro artifact (matches `python/compile/artifacts.py`).
const MICRO_BATCH: usize = 64;

fn micro_meta(name: &str, inputs: Vec<IoSpec>, kind: &str, n: usize) -> ArtifactMeta {
    ArtifactMeta {
        name: name.to_string(),
        file: "<native>".to_string(),
        inputs,
        outputs: vec!["y".to_string()],
        meta: Json::obj(vec![
            ("kind", Json::Str(kind.to_string())),
            ("n", Json::Num(n as f64)),
            ("batch", Json::Num(MICRO_BATCH as f64)),
        ]),
    }
}

fn spec_f32(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.to_string(), shape: shape.to_vec(), dtype: Dtype::F32 }
}

fn spec_i32(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.to_string(), shape: shape.to_vec(), dtype: Dtype::I32 }
}

/// Wrap raw i32 diagonal offsets into `[0, n_in)`, into a pooled buffer.
fn offsets_to_usize(offsets: &[i32], n_in: usize) -> Vec<usize> {
    let mut out = workspace::take_uninit_usize(offsets.len());
    for (o, &v) in out.iter_mut().zip(offsets) {
        *o = (((v as i64 % n_in as i64) + n_in as i64) % n_in as i64) as usize;
    }
    out
}

/// Parse and synthesize `micro_*` artifact names; `Ok(None)` = not a micro name.
fn micro_artifact(name: &str) -> Result<Option<Artifact>> {
    if let Some(n) = name.strip_prefix("micro_dense_n") {
        let n: usize = n.parse().map_err(|_| anyhow!("bad micro name '{}'", name))?;
        let meta = micro_meta(
            name,
            vec![spec_f32("x", &[MICRO_BATCH, n]), spec_f32("w", &[n, n])],
            "micro_dense",
            n,
        );
        let f: StepFn = Box::new(move |inputs| {
            let x = inputs[0].as_f32()?;
            let w = inputs[1].as_f32()?;
            let mut y = workspace::take_uninit_f32(MICRO_BATCH * n);
            dense::gemm_t(x, w, &mut y, MICRO_BATCH, n, n);
            Ok(vec![workspace::tensor_f32(&[MICRO_BATCH, n], y)])
        });
        return Ok(Some(Artifact::from_native(meta, f)));
    }
    if let Some(rest) = name.strip_prefix("micro_diag_n") {
        let Some((n, k)) = rest.split_once("_k") else {
            bail!("bad micro name '{}'", name);
        };
        let n: usize = n.parse().map_err(|_| anyhow!("bad micro name '{}'", name))?;
        let k: usize = k.parse().map_err(|_| anyhow!("bad micro name '{}'", name))?;
        let meta = micro_meta(
            name,
            vec![
                spec_f32("x", &[MICRO_BATCH, n]),
                spec_i32("offsets", &[k]),
                spec_f32("values", &[k, n]),
            ],
            "micro_diag",
            n,
        );
        let f: StepFn = Box::new(move |inputs| {
            let x = inputs[0].as_f32()?;
            let offsets = offsets_to_usize(inputs[1].as_i32()?, n);
            let values = inputs[2].as_f32()?;
            let mut y = workspace::take_uninit_f32(MICRO_BATCH * n);
            diag::spmm_t(x, &offsets, values, &mut y, MICRO_BATCH, n, n);
            workspace::give_usize(offsets);
            Ok(vec![workspace::tensor_f32(&[MICRO_BATCH, n], y)])
        });
        return Ok(Some(Artifact::from_native(meta, f)));
    }
    if let Some(rest) = name.strip_prefix("micro_bcsr_n") {
        let parts: Vec<&str> = rest.split('_').collect();
        if parts.len() != 3 {
            bail!("bad micro name '{}'", name);
        }
        let n: usize = parts[0].parse().map_err(|_| anyhow!("bad micro name '{}'", name))?;
        let nnzb: usize = parts[1]
            .strip_prefix("nnzb")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad micro name '{}'", name))?;
        let bs: usize = parts[2]
            .strip_prefix("bs")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad micro name '{}'", name))?;
        if bs == 0 || n % bs != 0 {
            bail!("micro_bcsr: n {} not divisible by bs {}", n, bs);
        }
        let nbr = n / bs;
        let meta = micro_meta(
            name,
            vec![
                spec_f32("x", &[MICRO_BATCH, n]),
                spec_i32("row_ptr", &[nbr + 1]),
                spec_i32("col_idx", &[nnzb]),
                spec_f32("blocks", &[nnzb, bs, bs]),
            ],
            "micro_bcsr",
            n,
        );
        let f: StepFn = Box::new(move |inputs| {
            let x = inputs[0].as_f32()?;
            let raw_rp = inputs[1].as_i32()?;
            let raw_ci = inputs[2].as_i32()?;
            let mut row_ptr = workspace::take_uninit_usize(raw_rp.len());
            for (o, &v) in row_ptr.iter_mut().zip(raw_rp) {
                *o = v.max(0) as usize;
            }
            let mut col_idx = workspace::take_uninit_usize(raw_ci.len());
            for (o, &v) in col_idx.iter_mut().zip(raw_ci) {
                *o = v.max(0) as usize;
            }
            let blocks = inputs[3].as_f32()?;
            // full CSR invariants: monotone row_ptr bounded by nnzb, so a
            // malformed input errors here instead of panicking in the kernel
            if row_ptr.windows(2).any(|w| w[0] > w[1])
                || row_ptr.last().copied().unwrap_or(0) > col_idx.len()
            {
                bail!("micro_bcsr: row_ptr not monotone within nnzb {}", col_idx.len());
            }
            if let Some(&bad) = col_idx.iter().find(|&&c| c * bs + bs > n) {
                bail!("micro_bcsr: block col {} out of range", bad);
            }
            let mut y = workspace::take_uninit_f32(MICRO_BATCH * n);
            bcsr::spmm_t(x, &row_ptr, &col_idx, blocks, bs, n, n, &mut y, MICRO_BATCH);
            workspace::give_usize(row_ptr);
            workspace::give_usize(col_idx);
            Ok(vec![workspace::tensor_f32(&[MICRO_BATCH, n], y)])
        });
        return Ok(Some(Artifact::from_native(meta, f)));
    }
    Ok(None)
}

// ---------------------------------------------------------------------------
// Native MLP model zoo
// ---------------------------------------------------------------------------

/// Pooled-patch MLP classifier config (the native analogue of the L2
/// `CONFIGS` table; datasets resolve by the usual `RunConfig` rules).
#[derive(Clone, Copy, Debug)]
pub struct MlpConfig {
    pub name: &'static str,
    pub tokens: usize,
    pub patch_dim: usize,
    pub dim: usize,
    pub mlp: usize,
    pub depth: usize,
    pub classes: usize,
    pub batch: usize,
    pub smoothing: f32,
}

/// Native model registry.
pub const MODELS: &[MlpConfig] = &[
    MlpConfig {
        name: "mlp_micro",
        tokens: 16,
        patch_dim: 48,
        dim: 64,
        mlp: 128,
        depth: 2,
        classes: 10,
        batch: 64,
        smoothing: 0.1,
    },
    MlpConfig {
        name: "mlp_tiny",
        tokens: 64,
        patch_dim: 48,
        dim: 128,
        mlp: 256,
        depth: 3,
        classes: 100,
        batch: 32,
        smoothing: 0.1,
    },
];

/// Sparse-layer parameterization (mirrors the L2 naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Param {
    Masked,
    DynaDiag,
}

impl Param {
    fn as_str(self) -> &'static str {
        match self {
            Param::Masked => "masked",
            Param::DynaDiag => "dynadiag",
        }
    }
}

/// Ordered (name, n_out, n_in) of the sparse layers — the `kvec` contract.
fn sparse_layers(cfg: &MlpConfig) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    for b in 0..cfg.depth {
        out.push((format!("blocks/{}/fc1", b), cfg.mlp, cfg.dim));
        out.push((format!("blocks/{}/fc2", b), cfg.dim, cfg.mlp));
    }
    out
}

/// Parameter leaves in deterministic flatten order (sorted full paths, the
/// `flatten_named` contract), without a section prefix.
fn param_leaves(cfg: &MlpConfig, mode: Param) -> Vec<(String, Vec<usize>)> {
    let mut out: Vec<(String, Vec<usize>)> = Vec::new();
    for b in 0..cfg.depth {
        for (ln, o, i) in [("fc1", cfg.mlp, cfg.dim), ("fc2", cfg.dim, cfg.mlp)] {
            let base = format!("blocks/{}/{}", b, ln);
            match mode {
                Param::Masked => {
                    out.push((format!("{}/b", base), vec![o]));
                    out.push((format!("{}/w", base), vec![o, i]));
                }
                Param::DynaDiag => {
                    out.push((format!("{}/alpha", base), vec![i]));
                    out.push((format!("{}/b", base), vec![o]));
                    out.push((format!("{}/v", base), vec![o, i]));
                }
            }
        }
    }
    out.push(("embed/b".to_string(), vec![cfg.dim]));
    out.push(("embed/w".to_string(), vec![cfg.dim, cfg.patch_dim]));
    out.push(("head/b".to_string(), vec![cfg.classes]));
    out.push(("head/w".to_string(), vec![cfg.classes, cfg.dim]));
    out
}

fn model_meta_json(cfg: &MlpConfig, kind: &str, param: &str) -> Json {
    Json::obj(vec![
        ("model", Json::Str(cfg.name.to_string())),
        ("kind", Json::Str(kind.to_string())),
        ("param", Json::Str(param.to_string())),
        (
            "config",
            Json::obj(vec![
                ("kind", Json::Str("mlp".to_string())),
                ("tokens", Json::Num(cfg.tokens as f64)),
                ("patch_dim", Json::Num(cfg.patch_dim as f64)),
                ("dim", Json::Num(cfg.dim as f64)),
                ("mlp", Json::Num(cfg.mlp as f64)),
                ("depth", Json::Num(cfg.depth as f64)),
                ("classes", Json::Num(cfg.classes as f64)),
                ("batch", Json::Num(cfg.batch as f64)),
                ("smoothing", Json::Num(cfg.smoothing as f64)),
            ]),
        ),
        (
            "sparse_layers",
            Json::Arr(
                sparse_layers(cfg)
                    .into_iter()
                    .map(|(n, o, i)| {
                        Json::obj(vec![
                            ("name", Json::Str(n)),
                            ("out", Json::Num(o as f64)),
                            ("in", Json::Num(i as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn batch_specs(cfg: &MlpConfig) -> Vec<IoSpec> {
    vec![
        spec_f32("batch/x", &[cfg.batch, cfg.tokens, cfg.patch_dim]),
        spec_i32("batch/y", &[cfg.batch]),
    ]
}

// ---------------------------------------------------------------------------
// IO plans: name routing resolved once at artifact-build time
// ---------------------------------------------------------------------------

fn spec_idx(specs: &[IoSpec], name: &str) -> usize {
    specs
        .iter()
        .position(|s| s.name == name)
        .unwrap_or_else(|| panic!("native plan: missing input '{}'", name))
}

fn spec_idx_opt(specs: &[IoSpec], name: &str) -> Option<usize> {
    specs.iter().position(|s| s.name == name)
}

/// One sparse layer's input slots.
struct LayerIo {
    n_out: usize,
    n_in: usize,
    bias: usize,
    /// masked: `params/<base>/w`; dynadiag: `params/<base>/v`
    w: usize,
    mask: Option<usize>,
    alpha: Option<usize>,
}

/// Input slots shared by the train/eval/gradprobe step functions.
struct ModelIo {
    x: usize,
    y: usize,
    temp: Option<usize>,
    kvec: Option<usize>,
    embed_w: usize,
    embed_b: usize,
    head_w: usize,
    head_b: usize,
    /// 2·depth entries, fc1/fc2 interleaved per block (the kvec order).
    layers: Vec<LayerIo>,
}

fn model_io(cfg: &MlpConfig, mode: Param, specs: &[IoSpec]) -> ModelIo {
    let mut layers = Vec::with_capacity(2 * cfg.depth);
    for b in 0..cfg.depth {
        for (ln, o, i) in [("fc1", cfg.mlp, cfg.dim), ("fc2", cfg.dim, cfg.mlp)] {
            let base = format!("blocks/{}/{}", b, ln);
            layers.push(LayerIo {
                n_out: o,
                n_in: i,
                bias: spec_idx(specs, &format!("params/{}/b", base)),
                w: match mode {
                    Param::Masked => spec_idx(specs, &format!("params/{}/w", base)),
                    Param::DynaDiag => spec_idx(specs, &format!("params/{}/v", base)),
                },
                mask: match mode {
                    Param::Masked => Some(spec_idx(specs, &format!("masks/{}", base))),
                    Param::DynaDiag => None,
                },
                alpha: match mode {
                    Param::Masked => None,
                    Param::DynaDiag => {
                        Some(spec_idx(specs, &format!("params/{}/alpha", base)))
                    }
                },
            });
        }
    }
    ModelIo {
        x: spec_idx(specs, "batch/x"),
        y: spec_idx(specs, "batch/y"),
        temp: spec_idx_opt(specs, "scalar/temp"),
        kvec: spec_idx_opt(specs, "kvec"),
        embed_w: spec_idx(specs, "params/embed/w"),
        embed_b: spec_idx(specs, "params/embed/b"),
        head_w: spec_idx(specs, "params/head/w"),
        head_b: spec_idx(specs, "params/head/b"),
        layers,
    }
}

/// Where one parameter leaf's gradient comes from. Layer indices are the
/// sparse-layer (kvec) order.
enum GradSrc {
    EmbedW,
    EmbedB,
    HeadW,
    HeadB,
    LayerBias(usize),
    /// masked weight: `dW = dW_eff ⊙ M`
    LayerW(usize),
    /// dynadiag values: `dV = dW_eff ⊙ Ã` (expanded per position)
    LayerV(usize),
    /// dynadiag α through the soft-TopK Jacobian
    LayerAlpha(usize),
}

fn grad_src_for(name: &str) -> GradSrc {
    match name {
        "embed/w" => GradSrc::EmbedW,
        "embed/b" => GradSrc::EmbedB,
        "head/w" => GradSrc::HeadW,
        "head/b" => GradSrc::HeadB,
        _ => {
            // "blocks/{b}/{fc1|fc2}/{b|w|v|alpha}"
            let parts: Vec<&str> = name.split('/').collect();
            assert_eq!(parts.len(), 4, "unexpected leaf '{}'", name);
            let bidx: usize = parts[1].parse().expect("block index");
            let l = 2 * bidx + if parts[2] == "fc2" { 1 } else { 0 };
            match parts[3] {
                "b" => GradSrc::LayerBias(l),
                "w" => GradSrc::LayerW(l),
                "v" => GradSrc::LayerV(l),
                "alpha" => GradSrc::LayerAlpha(l),
                other => panic!("unknown leaf kind '{}'", other),
            }
        }
    }
}

/// One parameter leaf's train-step slots.
struct LeafIo {
    p: usize,
    m: usize,
    v: usize,
    shape: Vec<usize>,
    decay: bool,
    src: GradSrc,
}

struct TrainPlan {
    io: ModelIo,
    step: usize,
    lr: usize,
    wd: usize,
    l1: Option<usize>,
    leaves: Vec<LeafIo>,
}

fn train_plan(cfg: &MlpConfig, mode: Param, specs: &[IoSpec]) -> TrainPlan {
    let io = model_io(cfg, mode, specs);
    let mut leaves = Vec::new();
    for (name, shape) in param_leaves(cfg, mode) {
        let decay = shape.len() >= 2 && !name.ends_with("alpha");
        leaves.push(LeafIo {
            p: spec_idx(specs, &format!("params/{}", name)),
            m: spec_idx(specs, &format!("opt_m/{}", name)),
            v: spec_idx(specs, &format!("opt_v/{}", name)),
            shape,
            decay,
            src: grad_src_for(&name),
        });
    }
    TrainPlan {
        io,
        step: spec_idx(specs, "scalar/step"),
        lr: spec_idx(specs, "scalar/lr"),
        wd: spec_idx(specs, "scalar/wd"),
        l1: spec_idx_opt(specs, "scalar/l1"),
        leaves,
    }
}

fn scalar_at(tensors: &[HostTensor], idx: usize) -> Result<f32> {
    Ok(tensors[idx].as_f32()?[0])
}

// ---------------------------------------------------------------------------
// Math helpers (forward / backward / optimizer)
// ---------------------------------------------------------------------------

/// `y = x @ Wᵀ + bias` into a workspace buffer (caller recycles).
/// `pub(crate)` so the batched serving forward ([`super::infer`]) reuses
/// the exact train-path arithmetic.
pub(crate) fn linear_fwd(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) -> Vec<f32> {
    let mut y = workspace::take_uninit_f32(b * n_out);
    dense::gemm_t(x, w, &mut y, b, n_in, n_out);
    for yr in y.chunks_exact_mut(n_out) {
        for (v, &bi) in yr.iter_mut().zip(bias) {
            *v += bi;
        }
    }
    y
}

/// Column sums of a `[rows, n]` buffer, into a workspace buffer.
fn col_sums(dy: &[f32], n: usize) -> Vec<f32> {
    let mut out = workspace::take_f32(n);
    for row in dy.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// Softmax cross-entropy with label smoothing; `dlogits` is `(p − q)/B`.
/// All three buffers come from the workspace.
struct CeOut {
    loss: f32,
    acc: f32,
    per_example: Vec<f32>,
    dlogits: Vec<f32>,
    preds: Vec<i32>,
}

fn recycle_ce(ce: CeOut) {
    workspace::give_f32(ce.per_example);
    workspace::give_f32(ce.dlogits);
    workspace::give_i32(ce.preds);
}

fn softmax_ce(logits: &[f32], y: &[i32], b: usize, c: usize, smoothing: f32) -> Result<CeOut> {
    let mut per_example = workspace::take_uninit_f32(b);
    let mut dlogits = workspace::take_uninit_f32(b * c);
    let mut preds = workspace::take_uninit_i32(b);
    let mut correct = 0usize;
    for bi in 0..b {
        let row = &logits[bi * c..(bi + 1) * c];
        let yi = y[bi];
        if yi < 0 || yi as usize >= c {
            bail!("label {} outside [0, {})", yi, c);
        }
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - m) as f64).exp();
        }
        let ln_sum = sum.ln() as f32;
        // arg max (ties to the lower index, like jnp.argmax)
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        preds[bi] = best as i32;
        if best == yi as usize {
            correct += 1;
        }
        let mut nll = 0.0f32;
        let mut uniform = 0.0f32;
        for j in 0..c {
            let logp = row[j] - m - ln_sum;
            if j == yi as usize {
                nll = -logp;
            }
            uniform -= logp;
        }
        uniform /= c as f32;
        per_example[bi] = (1.0 - smoothing) * nll + smoothing * uniform;
        let drow = &mut dlogits[bi * c..(bi + 1) * c];
        for j in 0..c {
            let p = (((row[j] - m) as f64).exp() / sum) as f32;
            let q = if j == yi as usize { 1.0 - smoothing + smoothing / c as f32 }
                else { smoothing / c as f32 };
            drow[j] = (p - q) / b as f32;
        }
    }
    let loss = per_example.iter().sum::<f32>() / b as f32;
    Ok(CeOut {
        loss,
        acc: correct as f32 / b as f32,
        per_example,
        dlogits,
        preds,
    })
}

/// One AdamW step matching `python/compile/optim.py` (decoupled decay on
/// matrix-shaped params only, never on α; bias correction from the 1-based
/// `step` scalar).
#[allow(clippy::too_many_arguments)]
fn adamw(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    step: f32,
    lr: f32,
    wd: f32,
    decay: bool,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let b1c = 1.0 - B1.powf(step);
    let b2c = 1.0 - B2.powf(step);
    for i in 0..p.len() {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
        let mh = m[i] / b1c;
        let vh = v[i] / b2c;
        let decay_term = if decay { lr * wd * p[i] } else { 0.0 };
        p[i] = p[i] - lr * mh / (vh.sqrt() + EPS) - decay_term;
    }
}

/// Effective weights of the whole model. Dense params are borrowed from
/// the step inputs; only the sparse layers materialize (into workspace
/// buffers, recycled by [`recycle_eff`]).
struct BlockEff<'a> {
    w1: Vec<f32>,
    b1: &'a [f32],
    w2: Vec<f32>,
    b2: &'a [f32],
}

struct EffParams<'a> {
    embed_w: &'a [f32],
    embed_b: &'a [f32],
    head_w: &'a [f32],
    head_b: &'a [f32],
    blocks: Vec<BlockEff<'a>>,
    /// per sparse layer (fc1, fc2 interleaved per block): the soft-TopK ᾱ
    /// expanded per candidate diagonal — DynaDiag only
    atilde: Vec<Vec<f32>>,
    /// Σ |α| over every sparse layer — DynaDiag only
    l1_sum: f32,
}

fn recycle_eff(eff: EffParams) {
    for blk in eff.blocks {
        workspace::give_f32(blk.w1);
        workspace::give_f32(blk.w2);
    }
    for at in eff.atilde {
        workspace::give_f32(at);
    }
}

/// `W_eff[i, j] = V[i, j] · ᾱ[(j − i) mod n_in]` (Eq. 4–5 composition).
/// The owner offset of row `i` starts at `(n_in − i) mod n_in` and walks
/// the ring exactly once per row, so each row splits into two contiguous
/// branch-free segments (same decomposition as the diag SpMM kernels);
/// rows are independent, so large layers go through the pool. Also reused
/// for the gradient mapping `dV = dW_eff ⊙ Ã` (identical index algebra).
fn compose_dynadiag_weff_into(
    v: &[f32],
    atilde: &[f32],
    n_out: usize,
    n_in: usize,
    w: &mut [f32],
) {
    debug_assert_eq!(v.len(), n_out * n_in);
    debug_assert_eq!(w.len(), n_out * n_in);
    debug_assert_eq!(atilde.len(), n_in);
    pool::parallel_rows(w, n_in, 2 * n_in, |first_row, chunk| {
        for (r, wr) in chunk.chunks_exact_mut(n_in).enumerate() {
            let i = first_row + r;
            let vr = &v[i * n_in..(i + 1) * n_in];
            let o0 = (n_in - (i % n_in)) % n_in;
            let split = n_in - o0;
            for ((wv, &vv), &av) in
                wr[..split].iter_mut().zip(&vr[..split]).zip(&atilde[o0..])
            {
                *wv = vv * av;
            }
            for ((wv, &vv), &av) in
                wr[split..].iter_mut().zip(&vr[split..]).zip(&atilde[..o0])
            {
                *wv = vv * av;
            }
        }
    });
}

fn build_eff<'a>(
    cfg: &MlpConfig,
    mode: Param,
    io: &ModelIo,
    tensors: &'a [HostTensor],
    temp: f32,
    kvec: Option<&[f32]>,
) -> Result<EffParams<'a>> {
    let mut blocks = Vec::with_capacity(cfg.depth);
    let mut atilde_all: Vec<Vec<f32>> = Vec::new();
    let mut l1_sum = 0.0f32;
    {
        let mut eff_layer = |l: usize| -> Result<(Vec<f32>, &'a [f32])> {
            let layer = &io.layers[l];
            let (o, i) = (layer.n_out, layer.n_in);
            let bias = tensors[layer.bias].as_f32()?;
            match mode {
                Param::Masked => {
                    let w = tensors[layer.w].as_f32()?;
                    let mask = tensors[layer.mask.expect("masked layer has mask")].as_f32()?;
                    if w.len() != o * i || mask.len() != o * i {
                        bail!("sparse layer {}: bad w/mask length", l);
                    }
                    let mut weff = workspace::take_uninit_f32(o * i);
                    for ((e, &a), &mk) in weff.iter_mut().zip(w).zip(mask) {
                        *e = a * mk;
                    }
                    Ok((weff, bias))
                }
                Param::DynaDiag => {
                    let v = tensors[layer.w].as_f32()?;
                    let alpha = tensors[layer.alpha.expect("dynadiag layer has alpha")].as_f32()?;
                    if v.len() != o * i || alpha.len() != i {
                        bail!("sparse layer {}: bad v/alpha length", l);
                    }
                    let k = kvec
                        .and_then(|kv| kv.get(l))
                        .copied()
                        .ok_or_else(|| anyhow!("kvec missing entry {}", l))?;
                    let st = soft_topk(alpha, k as f64, temp as f64);
                    let mut at = workspace::take_uninit_f32(i);
                    for (o_at, s) in at.iter_mut().zip(&st) {
                        *o_at = *s as f32;
                    }
                    l1_sum += alpha.iter().map(|a| a.abs()).sum::<f32>();
                    let mut weff = workspace::take_uninit_f32(o * i);
                    compose_dynadiag_weff_into(v, &at, o, i, &mut weff);
                    atilde_all.push(at);
                    Ok((weff, bias))
                }
            }
        };
        for b in 0..cfg.depth {
            let (w1, b1) = eff_layer(2 * b)?;
            let (w2, b2) = eff_layer(2 * b + 1)?;
            blocks.push(BlockEff { w1, b1, w2, b2 });
        }
    }
    Ok(EffParams {
        embed_w: tensors[io.embed_w].as_f32()?,
        embed_b: tensors[io.embed_b].as_f32()?,
        head_w: tensors[io.head_w].as_f32()?,
        head_b: tensors[io.head_b].as_f32()?,
        blocks,
        atilde: atilde_all,
        l1_sum,
    })
}

/// Activations the backward pass needs (all workspace buffers).
struct ForwardCache {
    pooled: Vec<f32>,
    /// h[0] = embed output; h[l+1] = output of block l; h[depth] feeds the head
    h: Vec<Vec<f32>>,
    zpre: Vec<Vec<f32>>,
    act: Vec<Vec<f32>>,
    logits: Vec<f32>,
}

fn recycle_cache(cache: ForwardCache) {
    workspace::give_f32(cache.pooled);
    for v in cache.h {
        workspace::give_f32(v);
    }
    for v in cache.zpre {
        workspace::give_f32(v);
    }
    for v in cache.act {
        workspace::give_f32(v);
    }
    workspace::give_f32(cache.logits);
}

/// Mean-pool the tokens: `[B, T, P] -> [B, P]` (the model's input stem,
/// shared by every parameterization including diag-infer and the batched
/// serving forward in [`super::infer`]). Returns a workspace buffer.
pub(crate) fn mean_pool(x: &[f32], b: usize, t: usize, p: usize) -> Vec<f32> {
    let mut pooled = workspace::take_f32(b * p);
    for bi in 0..b {
        let dst = &mut pooled[bi * p..(bi + 1) * p];
        for ti in 0..t {
            let src = &x[(bi * t + ti) * p..(bi * t + ti + 1) * p];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        for d in dst.iter_mut() {
            *d /= t as f32;
        }
    }
    pooled
}

fn forward(cfg: &MlpConfig, eff: &EffParams, x: &[f32]) -> ForwardCache {
    let (b, t, p) = (cfg.batch, cfg.tokens, cfg.patch_dim);
    let pooled = mean_pool(x, b, t, p);
    let mut h = Vec::with_capacity(cfg.depth + 1);
    h.push(linear_fwd(&pooled, eff.embed_w, eff.embed_b, b, p, cfg.dim));
    let mut zpre = Vec::with_capacity(cfg.depth);
    let mut act = Vec::with_capacity(cfg.depth);
    for blk in &eff.blocks {
        let hin = h.last().unwrap();
        let z = linear_fwd(hin, &blk.w1, blk.b1, b, cfg.dim, cfg.mlp);
        let mut a = workspace::take_uninit_f32(z.len());
        for (av, &zv) in a.iter_mut().zip(&z) {
            *av = gelu(zv);
        }
        let r = linear_fwd(&a, &blk.w2, blk.b2, b, cfg.mlp, cfg.dim);
        let mut hnext = workspace::take_copy_f32(hin);
        for (o, &v) in hnext.iter_mut().zip(&r) {
            *o += v;
        }
        workspace::give_f32(r);
        zpre.push(z);
        act.push(a);
        h.push(hnext);
    }
    let logits = linear_fwd(h.last().unwrap(), eff.head_w, eff.head_b, b, cfg.dim, cfg.classes);
    ForwardCache { pooled, h, zpre, act, logits }
}

/// Gradients w.r.t. the *effective* weights (masked/DynaDiag mapping happens
/// in the caller) plus the dense embed/head params. All workspace buffers.
struct Grads {
    embed_w: Vec<f32>,
    embed_b: Vec<f32>,
    head_w: Vec<f32>,
    head_b: Vec<f32>,
    /// per block: (dW1_eff, db1, dW2_eff, db2)
    blocks: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>,
}

fn recycle_grads(grads: Grads) {
    workspace::give_f32(grads.embed_w);
    workspace::give_f32(grads.embed_b);
    workspace::give_f32(grads.head_w);
    workspace::give_f32(grads.head_b);
    for (dw1, db1, dw2, db2) in grads.blocks {
        workspace::give_f32(dw1);
        workspace::give_f32(db1);
        workspace::give_f32(dw2);
        workspace::give_f32(db2);
    }
}

/// dW_eff of sparse layer `l` (kvec order) inside `grads`.
fn block_dweff(grads: &Grads, l: usize) -> &[f32] {
    let blk = &grads.blocks[l / 2];
    if l % 2 == 0 {
        &blk.0
    } else {
        &blk.2
    }
}

fn backward(cfg: &MlpConfig, eff: &EffParams, cache: &ForwardCache, dlogits: &[f32]) -> Grads {
    let b = cfg.batch;
    let (d, m, c, p) = (cfg.dim, cfg.mlp, cfg.classes, cfg.patch_dim);
    let mut head_w = workspace::take_uninit_f32(c * d);
    dense::gemm_grad_w(dlogits, cache.h.last().unwrap(), &mut head_w, b, d, c);
    let head_b = col_sums(dlogits, c);
    let mut dh = workspace::take_uninit_f32(b * d);
    dense::gemm(dlogits, eff.head_w, &mut dh, b, d, c);

    let mut blocks_rev = Vec::with_capacity(cfg.depth);
    for l in (0..cfg.depth).rev() {
        let blk = &eff.blocks[l];
        let hin = &cache.h[l];
        let a = &cache.act[l];
        let z = &cache.zpre[l];
        // residual branch: r = fc2(gelu(fc1(hin)))
        let dr = &dh; // dh/dr = identity on the residual add
        let mut dw2 = workspace::take_uninit_f32(d * m);
        dense::gemm_grad_w(dr, a, &mut dw2, b, m, d);
        let db2 = col_sums(dr, d);
        let mut da = workspace::take_uninit_f32(b * m);
        dense::gemm(dr, &blk.w2, &mut da, b, m, d);
        let mut dz = workspace::take_uninit_f32(b * m);
        for ((o, &g), &zv) in dz.iter_mut().zip(&da).zip(z) {
            *o = g * gelu_prime(zv);
        }
        workspace::give_f32(da);
        let mut dw1 = workspace::take_uninit_f32(m * d);
        dense::gemm_grad_w(&dz, hin, &mut dw1, b, d, m);
        let db1 = col_sums(&dz, m);
        let mut dh_branch = workspace::take_uninit_f32(b * d);
        dense::gemm(&dz, &blk.w1, &mut dh_branch, b, d, m);
        workspace::give_f32(dz);
        for (o, &v) in dh.iter_mut().zip(&dh_branch) {
            *o += v; // identity path + branch path
        }
        workspace::give_f32(dh_branch);
        blocks_rev.push((dw1, db1, dw2, db2));
    }
    blocks_rev.reverse();

    let mut embed_w = workspace::take_uninit_f32(d * p);
    dense::gemm_grad_w(&dh, &cache.pooled, &mut embed_w, b, p, d);
    let embed_b = col_sums(&dh, d);
    workspace::give_f32(dh);
    Grads {
        embed_w,
        embed_b,
        head_w,
        head_b,
        blocks: blocks_rev,
    }
}

/// α gradient through `ᾱ = min(k · softmax(α/T), 1)`: exact softmax
/// Jacobian with the saturated entries masked out, plus the ℓ1 term.
/// Writes into `out` (len == alpha.len()).
fn alpha_grad_into(
    alpha: &[f32],
    datilde: &[f32],
    k: f32,
    temp: f32,
    l1_coeff: f32,
    out: &mut [f32],
) {
    let t = (temp as f64).max(1e-6);
    let mx = alpha.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let mut s = workspace::take_uninit_f32(alpha.len());
    let mut sum = 0.0f64;
    for (sv, &a) in s.iter_mut().zip(alpha) {
        let e = ((a as f64 - mx) / t).exp();
        *sv = e as f32;
        sum += e;
    }
    let kk = k as f64;
    let mut inner = 0.0f64;
    for o in 0..alpha.len() {
        let so = s[o] as f64 / sum;
        if kk * so < 1.0 {
            inner += so * datilde[o] as f64;
        }
    }
    for pi in 0..alpha.len() {
        let sp = s[pi] as f64 / sum;
        let own = if kk * sp < 1.0 { sp * datilde[pi] as f64 } else { 0.0 };
        let soft = (kk / t) * (own - sp * inner);
        let l1 = l1_coeff
            * if alpha[pi] > 0.0 {
                1.0
            } else if alpha[pi] < 0.0 {
                -1.0
            } else {
                0.0
            };
        out[pi] = soft as f32 + l1;
    }
    workspace::give_f32(s);
}

/// `dᾱ[o] = Σ_{(i,j) on diagonal o} dW_eff[i,j] · V[i,j]`, into `out`
/// (zeroed, len == n_in). Rows share the accumulator, so this stays
/// serial, but each row is the same two-segment branch-free walk as the
/// compose above.
fn datilde_of_into(dweff: &[f32], v: &[f32], n_out: usize, n_in: usize, out: &mut [f32]) {
    for i in 0..n_out {
        let dr = &dweff[i * n_in..(i + 1) * n_in];
        let vr = &v[i * n_in..(i + 1) * n_in];
        let o0 = (n_in - (i % n_in)) % n_in;
        let split = n_in - o0;
        for ((o, &d), &vv) in out[o0..].iter_mut().zip(&dr[..split]).zip(&vr[..split]) {
            *o += d * vv;
        }
        for ((o, &d), &vv) in out[..o0].iter_mut().zip(&dr[split..]).zip(&vr[split..]) {
            *o += d * vv;
        }
    }
}

// ---------------------------------------------------------------------------
// Model artifacts
// ---------------------------------------------------------------------------

fn section_specs(leaves: &[(String, Vec<usize>)], prefix: &str) -> Vec<IoSpec> {
    leaves
        .iter()
        .map(|(n, shape)| spec_f32(&format!("{}{}", prefix, n), shape))
        .collect()
}

fn train_artifact(cfg: &'static MlpConfig, mode: Param) -> Artifact {
    let leaves = param_leaves(cfg, mode);
    let sparse = sparse_layers(cfg);
    let mut inputs = section_specs(&leaves, "params/");
    inputs.extend(section_specs(&leaves, "opt_m/"));
    inputs.extend(section_specs(&leaves, "opt_v/"));
    if mode == Param::Masked {
        for (name, o, i) in &sparse {
            inputs.push(spec_f32(&format!("masks/{}", name), &[*o, *i]));
        }
    }
    inputs.extend(batch_specs(cfg));
    inputs.push(spec_f32("scalar/step", &[]));
    inputs.push(spec_f32("scalar/lr", &[]));
    inputs.push(spec_f32("scalar/wd", &[]));
    if mode == Param::DynaDiag {
        inputs.push(spec_f32("scalar/temp", &[]));
        inputs.push(spec_f32("scalar/l1", &[]));
        inputs.push(spec_f32("kvec", &[sparse.len()]));
    }
    let mut outputs: Vec<String> = leaves.iter().map(|(n, _)| format!("params/{}", n)).collect();
    outputs.extend(leaves.iter().map(|(n, _)| format!("opt_m/{}", n)));
    outputs.extend(leaves.iter().map(|(n, _)| format!("opt_v/{}", n)));
    outputs.push("loss".to_string());
    outputs.push("acc".to_string());

    let plan = train_plan(cfg, mode, &inputs);
    let meta = ArtifactMeta {
        name: format!("{}_{}_train", cfg.name, mode.as_str()),
        file: "<native>".to_string(),
        inputs,
        outputs,
        meta: model_meta_json(cfg, "train", mode.as_str()),
    };

    let f: StepFn = Box::new(move |tensors| run_train(cfg, mode, &plan, tensors));
    Artifact::from_native(meta, f)
}

fn run_train(
    cfg: &MlpConfig,
    mode: Param,
    plan: &TrainPlan,
    tensors: &[HostTensor],
) -> Result<Vec<HostTensor>> {
    let io = &plan.io;
    let x = tensors[io.x].as_f32()?;
    let y = tensors[io.y].as_i32()?;
    let step = scalar_at(tensors, plan.step)?;
    let lr = scalar_at(tensors, plan.lr)?;
    let wd = scalar_at(tensors, plan.wd)?;
    let (temp, l1c, kvec) = match mode {
        Param::DynaDiag => (
            scalar_at(tensors, io.temp.expect("dynadiag train has temp"))?,
            scalar_at(tensors, plan.l1.expect("dynadiag train has l1"))?,
            Some(tensors[io.kvec.expect("dynadiag train has kvec")].as_f32()?),
        ),
        Param::Masked => (0.0, 0.0, None),
    };

    let eff = build_eff(cfg, mode, io, tensors, temp, kvec)?;
    let cache = forward(cfg, &eff, x);
    let ce = softmax_ce(&cache.logits, y, cfg.batch, cfg.classes, cfg.smoothing)?;
    let grads = backward(cfg, &eff, &cache, &ce.dlogits);
    let loss = ce.loss + l1c * eff.l1_sum;
    let acc = ce.acc;

    // AdamW over every parameter leaf, reading gradients straight from
    // their precomputed sources (no name routing on the step path)
    let n_leaves = plan.leaves.len();
    let mut new_p: Vec<HostTensor> = Vec::with_capacity(n_leaves);
    let mut new_m: Vec<HostTensor> = Vec::with_capacity(n_leaves);
    let mut new_v: Vec<HostTensor> = Vec::with_capacity(n_leaves);
    for leaf in &plan.leaves {
        let mut p = workspace::take_copy_f32(tensors[leaf.p].as_f32()?);
        let mut m = workspace::take_copy_f32(tensors[leaf.m].as_f32()?);
        let mut v = workspace::take_copy_f32(tensors[leaf.v].as_f32()?);
        // mapped gradients land in a pooled temp; dense ones are borrowed
        let mut tmp: Option<Vec<f32>> = None;
        let g: &[f32] = match leaf.src {
            GradSrc::EmbedW => &grads.embed_w,
            GradSrc::EmbedB => &grads.embed_b,
            GradSrc::HeadW => &grads.head_w,
            GradSrc::HeadB => &grads.head_b,
            GradSrc::LayerBias(l) => {
                let blk = &grads.blocks[l / 2];
                if l % 2 == 0 {
                    &blk.1
                } else {
                    &blk.3
                }
            }
            GradSrc::LayerW(l) => {
                let dweff = block_dweff(&grads, l);
                let mask = tensors[io.layers[l].mask.expect("masked layer")].as_f32()?;
                let mut t = workspace::take_uninit_f32(dweff.len());
                for ((o, &gw), &mk) in t.iter_mut().zip(dweff).zip(mask) {
                    *o = gw * mk;
                }
                tmp = Some(t);
                tmp.as_deref().unwrap()
            }
            GradSrc::LayerV(l) => {
                let dweff = block_dweff(&grads, l);
                let at = &eff.atilde[l];
                let (o_n, i_n) = (io.layers[l].n_out, io.layers[l].n_in);
                let mut t = workspace::take_uninit_f32(dweff.len());
                // dV = dW_eff ⊙ Ã — the same per-position expansion as the
                // forward compose, so it reuses the two-segment kernel
                compose_dynadiag_weff_into(dweff, at, o_n, i_n, &mut t);
                tmp = Some(t);
                tmp.as_deref().unwrap()
            }
            GradSrc::LayerAlpha(l) => {
                let dweff = block_dweff(&grads, l);
                let vvals = tensors[io.layers[l].w].as_f32()?;
                let alpha = tensors[io.layers[l].alpha.expect("dynadiag layer")].as_f32()?;
                let (o_n, i_n) = (io.layers[l].n_out, io.layers[l].n_in);
                let mut dat = workspace::take_f32(i_n);
                datilde_of_into(dweff, vvals, o_n, i_n, &mut dat);
                let kq = kvec.expect("dynadiag kvec")[l];
                let mut t = workspace::take_uninit_f32(i_n);
                alpha_grad_into(alpha, &dat, kq, temp, l1c, &mut t);
                workspace::give_f32(dat);
                tmp = Some(t);
                tmp.as_deref().unwrap()
            }
        };
        if g.len() != p.len() {
            bail!("gradient length mismatch for leaf (got {}, want {})", g.len(), p.len());
        }
        adamw(&mut p, g, &mut m, &mut v, step, lr, wd, leaf.decay);
        if let Some(t) = tmp {
            workspace::give_f32(t);
        }
        new_p.push(workspace::tensor_f32(&leaf.shape, p));
        new_m.push(workspace::tensor_f32(&leaf.shape, m));
        new_v.push(workspace::tensor_f32(&leaf.shape, v));
    }

    recycle_grads(grads);
    recycle_cache(cache);
    recycle_ce(ce);
    recycle_eff(eff);

    // outputs in meta order: params, opt_m, opt_v, loss, acc
    let mut out = Vec::with_capacity(3 * n_leaves + 2);
    out.extend(new_p);
    out.extend(new_m);
    out.extend(new_v);
    out.push(workspace::tensor_scalar(loss));
    out.push(workspace::tensor_scalar(acc));
    Ok(out)
}

fn eval_artifact(cfg: &'static MlpConfig, mode: Param) -> Artifact {
    let leaves = param_leaves(cfg, mode);
    let sparse = sparse_layers(cfg);
    let mut inputs = section_specs(&leaves, "params/");
    if mode == Param::Masked {
        for (name, o, i) in &sparse {
            inputs.push(spec_f32(&format!("masks/{}", name), &[*o, *i]));
        }
    }
    inputs.extend(batch_specs(cfg));
    if mode == Param::DynaDiag {
        inputs.push(spec_f32("scalar/temp", &[]));
        inputs.push(spec_f32("kvec", &[sparse.len()]));
    }
    let io = model_io(cfg, mode, &inputs);
    let meta = ArtifactMeta {
        name: format!("{}_{}_eval", cfg.name, mode.as_str()),
        file: "<native>".to_string(),
        inputs,
        outputs: vec!["loss".to_string(), "loss_vec".to_string(), "preds".to_string()],
        meta: model_meta_json(cfg, "eval", mode.as_str()),
    };
    let f: StepFn = Box::new(move |tensors| {
        let x = tensors[io.x].as_f32()?;
        let y = tensors[io.y].as_i32()?;
        let (temp, kvec) = match mode {
            Param::DynaDiag => (
                scalar_at(tensors, io.temp.expect("dynadiag eval has temp"))?,
                Some(tensors[io.kvec.expect("dynadiag eval has kvec")].as_f32()?),
            ),
            Param::Masked => (0.0, None),
        };
        let eff = build_eff(cfg, mode, &io, tensors, temp, kvec)?;
        let cache = forward(cfg, &eff, x);
        // evaluation reports un-smoothed CE (the L2 eval contract)
        let ce = softmax_ce(&cache.logits, y, cfg.batch, cfg.classes, 0.0)?;
        recycle_cache(cache);
        recycle_eff(eff);
        let CeOut { loss, per_example, dlogits, preds, .. } = ce;
        workspace::give_f32(dlogits);
        Ok(vec![
            workspace::tensor_scalar(loss),
            workspace::tensor_f32(&[cfg.batch], per_example),
            workspace::tensor_i32(&[cfg.batch], preds),
        ])
    });
    Artifact::from_native(meta, f)
}

fn gradprobe_artifact(cfg: &'static MlpConfig) -> Artifact {
    let leaves = param_leaves(cfg, Param::Masked);
    let sparse = sparse_layers(cfg);
    let mut inputs = section_specs(&leaves, "params/");
    for (name, o, i) in &sparse {
        inputs.push(spec_f32(&format!("masks/{}", name), &[*o, *i]));
    }
    inputs.extend(batch_specs(cfg));
    // grad outputs sorted by layer name (the python `sorted(grads.keys())`
    // contract). The step closure emits grads in construction order, so
    // the two orders must coincide — true while block indices stay single
    // digit; the assert trips before a depth >= 10 model can silently
    // mislabel its outputs.
    let outputs_unsorted: Vec<String> =
        sparse.iter().map(|(n, _, _)| format!("grad/{}", n)).collect();
    let mut outputs = outputs_unsorted.clone();
    outputs.sort();
    assert_eq!(
        outputs, outputs_unsorted,
        "gradprobe output routing assumes construction order == sorted order"
    );
    outputs.push("loss".to_string());
    let io = model_io(cfg, Param::Masked, &inputs);
    let meta = ArtifactMeta {
        name: format!("{}_masked_gradprobe", cfg.name),
        file: "<native>".to_string(),
        inputs,
        outputs,
        meta: model_meta_json(cfg, "gradprobe", "masked"),
    };
    let f: StepFn = Box::new(move |tensors| {
        let x = tensors[io.x].as_f32()?;
        let y = tensors[io.y].as_i32()?;
        let eff = build_eff(cfg, Param::Masked, &io, tensors, 0.0, None)?;
        let cache = forward(cfg, &eff, x);
        let ce = softmax_ce(&cache.logits, y, cfg.batch, cfg.classes, cfg.smoothing)?;
        let grads = backward(cfg, &eff, &cache, &ce.dlogits);
        let loss = ce.loss;
        recycle_cache(cache);
        recycle_ce(ce);
        recycle_eff(eff);
        // dense d loss / d W_eff per sparse layer, in sorted == construction
        // order (blocks/0/fc1, blocks/0/fc2, blocks/1/fc1, ...)
        let Grads { embed_w, embed_b, head_w, head_b, blocks } = grads;
        workspace::give_f32(embed_w);
        workspace::give_f32(embed_b);
        workspace::give_f32(head_w);
        workspace::give_f32(head_b);
        let mut out = Vec::with_capacity(2 * cfg.depth + 1);
        for (dw1, db1, dw2, db2) in blocks {
            out.push(workspace::tensor_f32(&[cfg.mlp, cfg.dim], dw1));
            out.push(workspace::tensor_f32(&[cfg.dim, cfg.mlp], dw2));
            workspace::give_f32(db1);
            workspace::give_f32(db2);
        }
        out.push(workspace::tensor_scalar(loss));
        Ok(out)
    });
    Artifact::from_native(meta, f)
}

use crate::sparsity::diagonal::diag_count as diag_k;

/// One sparse layer's diag-infer input slots.
struct InferLayer {
    bias: usize,
    offsets: usize,
    values: usize,
    n_out: usize,
    n_in: usize,
}

fn diag_infer_artifact(cfg: &'static MlpConfig, sparsity: f64) -> Artifact {
    let sparse = sparse_layers(cfg);
    // flatten order within a sparse layer: b < offsets < values
    let mut inputs: Vec<IoSpec> = Vec::new();
    let mut ks = Vec::new();
    for b in 0..cfg.depth {
        for (ln, o, i) in [("fc1", cfg.mlp, cfg.dim), ("fc2", cfg.dim, cfg.mlp)] {
            let base = format!("blocks/{}/{}", b, ln);
            let k = diag_k(i, sparsity);
            ks.push(k);
            inputs.push(spec_f32(&format!("params/{}/b", base), &[o]));
            inputs.push(spec_i32(&format!("params/{}/offsets", base), &[k]));
            inputs.push(spec_f32(&format!("params/{}/values", base), &[k, o]));
        }
    }
    inputs.push(spec_f32("params/embed/b", &[cfg.dim]));
    inputs.push(spec_f32("params/embed/w", &[cfg.dim, cfg.patch_dim]));
    inputs.push(spec_f32("params/head/b", &[cfg.classes]));
    inputs.push(spec_f32("params/head/w", &[cfg.classes, cfg.dim]));
    inputs.extend(batch_specs(cfg));

    // index plan
    let mut layers = Vec::with_capacity(2 * cfg.depth);
    for b in 0..cfg.depth {
        for (ln, o, i) in [("fc1", cfg.mlp, cfg.dim), ("fc2", cfg.dim, cfg.mlp)] {
            let base = format!("blocks/{}/{}", b, ln);
            layers.push(InferLayer {
                bias: spec_idx(&inputs, &format!("params/{}/b", base)),
                offsets: spec_idx(&inputs, &format!("params/{}/offsets", base)),
                values: spec_idx(&inputs, &format!("params/{}/values", base)),
                n_out: o,
                n_in: i,
            });
        }
    }
    let embed_w = spec_idx(&inputs, "params/embed/w");
    let embed_b = spec_idx(&inputs, "params/embed/b");
    let head_w = spec_idx(&inputs, "params/head/w");
    let head_b = spec_idx(&inputs, "params/head/b");
    let x_in = spec_idx(&inputs, "batch/x");
    let y_in = spec_idx(&inputs, "batch/y");

    let mut meta_json = model_meta_json(cfg, "diag_infer", "diag");
    if let Json::Obj(map) = &mut meta_json {
        map.insert("sparsity".to_string(), Json::Num(sparsity));
        map.insert(
            "diag_k".to_string(),
            Json::Obj(
                sparse
                    .iter()
                    .zip(&ks)
                    .map(|((n, _, _), &k)| (n.clone(), Json::Num(k as f64)))
                    .collect(),
            ),
        );
    }
    let pct = (sparsity * 100.0).round() as u32;
    let meta = ArtifactMeta {
        name: format!("{}_diag_infer{}", cfg.name, pct),
        file: "<native>".to_string(),
        inputs,
        outputs: vec!["loss".to_string(), "preds".to_string()],
        meta: meta_json,
    };
    let f: StepFn = Box::new(move |tensors| {
        let x = tensors[x_in].as_f32()?;
        let y = tensors[y_in].as_i32()?;
        let (b, t, p) = (cfg.batch, cfg.tokens, cfg.patch_dim);
        let pooled = mean_pool(x, b, t, p);
        let mut h = linear_fwd(
            &pooled,
            tensors[embed_w].as_f32()?,
            tensors[embed_b].as_f32()?,
            b,
            p,
            cfg.dim,
        );
        workspace::give_f32(pooled);
        let sparse_fwd = |layer: &InferLayer, hin: &[f32]| -> Result<Vec<f32>> {
            let (o, i) = (layer.n_out, layer.n_in);
            let offsets = offsets_to_usize(tensors[layer.offsets].as_i32()?, i);
            let values = tensors[layer.values].as_f32()?;
            let bias = tensors[layer.bias].as_f32()?;
            let mut z = workspace::take_uninit_f32(b * o);
            diag::spmm_t(hin, &offsets, values, &mut z, b, i, o);
            workspace::give_usize(offsets);
            for zr in z.chunks_exact_mut(o) {
                for (v, &bb) in zr.iter_mut().zip(bias) {
                    *v += bb;
                }
            }
            Ok(z)
        };
        for pair in layers.chunks_exact(2) {
            let z = sparse_fwd(&pair[0], &h)?;
            let mut a = workspace::take_uninit_f32(z.len());
            for (av, &zv) in a.iter_mut().zip(&z) {
                *av = gelu(zv);
            }
            workspace::give_f32(z);
            let r = sparse_fwd(&pair[1], &a)?;
            workspace::give_f32(a);
            for (o, &v) in h.iter_mut().zip(&r) {
                *o += v;
            }
            workspace::give_f32(r);
        }
        let logits = linear_fwd(
            &h,
            tensors[head_w].as_f32()?,
            tensors[head_b].as_f32()?,
            b,
            cfg.dim,
            cfg.classes,
        );
        workspace::give_f32(h);
        let ce = softmax_ce(&logits, y, b, cfg.classes, 0.0)?;
        workspace::give_f32(logits);
        let CeOut { loss, per_example, dlogits, preds, .. } = ce;
        workspace::give_f32(per_example);
        workspace::give_f32(dlogits);
        Ok(vec![
            workspace::tensor_scalar(loss),
            workspace::tensor_i32(&[b], preds),
        ])
    });
    Artifact::from_native(meta, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::diagonal::owner_offset;
    use crate::util::rng::Rng;

    fn owner_check(n_in: usize) {
        // the carry-walk in compose/datilde must agree with owner_offset
        for i in 0..3 * n_in {
            let mut off = (n_in - (i % n_in)) % n_in;
            for j in 0..n_in {
                assert_eq!(off, owner_offset(i, j, n_in), "i={} j={}", i, j);
                off += 1;
                if off == n_in {
                    off = 0;
                }
            }
        }
    }

    #[test]
    fn owner_walk_matches_owner_offset() {
        owner_check(4);
        owner_check(7);
        owner_check(16);
    }

    /// The two-segment compose / datilde walks agree with the direct
    /// `(j − i) mod n_in` owner formula on square, tall, and wide layers.
    #[test]
    fn compose_and_datilde_match_owner_formula() {
        let mut rng = Rng::new(33);
        for &(o, i) in &[(6usize, 4usize), (4, 6), (7, 7), (16, 5), (5, 16)] {
            let v: Vec<f32> = (0..o * i).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let at: Vec<f32> = (0..i).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut w = vec![0.0f32; o * i];
            compose_dynadiag_weff_into(&v, &at, o, i, &mut w);
            for r in 0..o {
                for j in 0..i {
                    let want = v[r * i + j] * at[owner_offset(r, j, i)];
                    assert!(
                        (w[r * i + j] - want).abs() < 1e-6,
                        "compose o={} i={} r={} j={}",
                        o,
                        i,
                        r,
                        j
                    );
                }
            }
            let dw: Vec<f32> = (0..o * i).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut dat = vec![0.0f32; i];
            datilde_of_into(&dw, &v, o, i, &mut dat);
            let mut want = vec![0.0f32; i];
            for r in 0..o {
                for j in 0..i {
                    want[owner_offset(r, j, i)] += dw[r * i + j] * v[r * i + j];
                }
            }
            for (idx, (a, b)) in dat.iter().zip(&want).enumerate() {
                assert!((a - b).abs() < 1e-4, "datilde o={} i={} off={}", o, i, idx);
            }
        }
    }

    #[test]
    fn workspace_reuses_buffers() {
        workspace::reset_stats();
        let a = workspace::take_f32(128);
        workspace::give_f32(a);
        let b = workspace::take_f32(64);
        let (fresh, reused) = workspace::stats();
        assert_eq!(fresh, 1, "first take allocates");
        assert_eq!(reused, 1, "second take reuses");
        assert_eq!(b.len(), 64);
        assert!(b.iter().all(|&v| v == 0.0), "takes are zeroed");
        workspace::give_f32(b);
        // take_uninit keeps length semantics but skips the memset on a
        // same-length reuse (contents unspecified)
        let u = workspace::take_uninit_f32(64);
        assert_eq!(u.len(), 64);
        workspace::give_f32(u);
        let t = workspace::tensor_scalar(3.5);
        assert_eq!(t.scalar().unwrap(), 3.5);
        workspace::give_tensor(t);
    }

    #[test]
    fn micro_dense_matches_reference() {
        let backend = NativeBackend::new();
        let art = backend.load("micro_dense_n32").unwrap();
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..MICRO_BATCH * 32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let w: Vec<f32> = (0..32 * 32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out = art
            .run(&[
                HostTensor::f32(&[MICRO_BATCH, 32], x.clone()),
                HostTensor::f32(&[32, 32], w.clone()),
            ])
            .unwrap();
        let xt = crate::tensor::Tensor::from_vec(&[MICRO_BATCH, 32], x).unwrap();
        let wt = crate::tensor::Tensor::from_vec(&[32, 32], w).unwrap();
        let want = wt.matmul_t(&xt).unwrap();
        let got = out[0].as_f32().unwrap();
        let diff = want.data.iter().zip(got).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-3, "diff {}", diff);
    }

    #[test]
    fn micro_diag_matches_diag_matrix() {
        let backend = NativeBackend::new();
        let (n, k) = (24usize, 5usize);
        let art = backend.load(&format!("micro_diag_n{}_k{}", n, k)).unwrap();
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..MICRO_BATCH * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let offs: Vec<i32> = rng.choose_k(n, k).into_iter().map(|o| o as i32).collect();
        let vals: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out = art
            .run(&[
                HostTensor::f32(&[MICRO_BATCH, n], x.clone()),
                HostTensor::i32(&[k], offs.clone()),
                HostTensor::f32(&[k, n], vals.clone()),
            ])
            .unwrap();
        let mut d = crate::sparsity::diagonal::DiagMatrix::new(
            n,
            n,
            offs.iter().map(|&o| o as usize).collect(),
        );
        for j in 0..k {
            for i in 0..n {
                d.values[j][i] = vals[j * n + i];
            }
        }
        let xt = crate::tensor::Tensor::from_vec(&[MICRO_BATCH, n], x).unwrap();
        let want = d.matmul_t(&xt).unwrap();
        let got = out[0].as_f32().unwrap();
        let diff = want.data.iter().zip(got).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-4, "diff {}", diff);
    }

    #[test]
    fn unknown_artifacts_error_clearly() {
        let backend = NativeBackend::new();
        let err = backend.load("vit_micro_masked_train").unwrap_err();
        let msg = format!("{:#}", err);
        assert!(msg.contains("native backend"), "{}", msg);
        assert!(backend.load("micro_dense_nXX").is_err());
    }

    #[test]
    fn train_meta_contract_is_complete() {
        let backend = NativeBackend::new();
        for name in ["mlp_micro_masked_train", "mlp_micro_dynadiag_train"] {
            let art = backend.load(name).unwrap();
            assert_eq!(art.meta.sparse_layers().unwrap().len(), 4);
            assert!(art.meta.input_index("batch/x").is_ok());
            assert!(art.meta.output_index("loss").is_ok());
            assert!(art.meta.output_index("acc").is_ok());
            // every params/opt input is also an output (the absorb contract)
            for spec in &art.meta.inputs {
                if spec.name.starts_with("params/") || spec.name.starts_with("opt_") {
                    assert!(
                        art.meta.output_index(&spec.name).is_ok(),
                        "{} missing output {}",
                        name,
                        spec.name
                    );
                }
            }
            assert_eq!(art.meta.config_usize("batch").unwrap(), 64);
        }
    }

    /// A fixed batch, repeated AdamW steps: loss must fall. This is the
    /// native analogue of the XLA `masked_train_step_runs_and_learns` test.
    #[test]
    fn masked_train_step_learns_on_fixed_batch() {
        let backend = NativeBackend::new();
        let art = backend.load("mlp_micro_masked_train").unwrap();
        let mut rng = Rng::new(5);
        let mut inputs: Vec<HostTensor> = Vec::new();
        for spec in &art.meta.inputs {
            let n: usize = spec.shape.iter().product();
            let t = if spec.name.starts_with("params/") {
                let fan = *spec.shape.last().unwrap_or(&1) as f32;
                let std = if spec.shape.len() >= 2 {
                    (2.0 / (fan + spec.shape[0] as f32)).sqrt()
                } else {
                    0.02
                };
                HostTensor::f32(&spec.shape, (0..n).map(|_| rng.normal_f32(0.0, std)).collect())
            } else if spec.name.starts_with("masks/") {
                HostTensor::f32(&spec.shape, vec![1.0; n])
            } else if spec.name == "batch/x" {
                HostTensor::f32(&spec.shape, (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            } else if spec.name == "batch/y" {
                HostTensor::i32(&spec.shape, (0..n).map(|_| rng.below(10) as i32).collect())
            } else if spec.name == "scalar/lr" {
                HostTensor::scalar_f32(3e-3)
            } else if spec.name == "scalar/step" {
                HostTensor::scalar_f32(1.0)
            } else {
                HostTensor::zeros(spec)
            };
            inputs.push(t);
        }
        let loss_idx = art.meta.output_index("loss").unwrap();
        let mut first = None;
        let mut last = 0.0;
        for step in 1..=16 {
            let out = art.run(&inputs).unwrap();
            last = out[loss_idx].scalar().unwrap();
            assert!(last.is_finite(), "loss diverged: {}", last);
            if first.is_none() {
                first = Some(last);
            }
            for (i, spec) in art.meta.inputs.iter().enumerate() {
                if spec.name.starts_with("params/")
                    || spec.name.starts_with("opt_m/")
                    || spec.name.starts_with("opt_v/")
                {
                    let oi = art.meta.output_index(&spec.name).unwrap();
                    inputs[i] = out[oi].clone();
                } else if spec.name == "scalar/step" {
                    inputs[i] = HostTensor::scalar_f32((step + 1) as f32);
                }
            }
        }
        let first = first.unwrap();
        assert!(last < first - 0.05, "loss did not decrease: {} -> {}", first, last);
    }
}
