//! The training loop: one XLA train-step artifact driven step-by-step, with
//! all Dynamic-Sparse-Training decisions made here between steps.
//!
//! Per step (Fig 3's loop, L3 view):
//!   1. compute schedules (lr, and for DynaDiag: T, kvec, ℓ1),
//!   2. build the input list by manifest name (params/opt from the
//!      [`ParamStore`], masks from the DST method, batch from [`DataSource`]),
//!   3. execute; absorb params'/opt' back into the store,
//!   4. at topology-update steps (masked methods): optionally run the
//!      grad-probe artifact, then prune-and-regrow each layer's mask and
//!      re-initialize regrown weights/moments.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::artifact::checkpoint::TrainCheckpoint;
use crate::config::{MethodKind, RunConfig};
use crate::data::corpus::Corpus;
use crate::data::VisionDataset;
use crate::dst::dynadiag::DynaDiagController;
use crate::dst::{self, DstMethod, GrowAction};
use std::rc::Rc;

use crate::runtime::native::workspace;
use crate::runtime::{Artifact, HostTensor, Session};
use crate::sparsity::diagonal::DiagMatrix;
use crate::sparsity::distribution::{allocate, LayerShape};
use crate::sparsity::mask::Mask;
use crate::sparsity::schedule::{lr_at, rigl_update_fraction};
use crate::tensor::Tensor;
use crate::train::state::ParamStore;
use crate::util::rng::Rng;

/// Synthetic data source matching a model family.
pub enum DataSource {
    Vision(VisionDataset),
    Lm(Corpus),
}

impl DataSource {
    pub fn for_run(cfg: &RunConfig) -> Result<DataSource> {
        let name = if cfg.dataset.is_empty() {
            RunConfig::infer_dataset(&cfg.model).to_string()
        } else {
            cfg.dataset.clone()
        };
        match name.as_str() {
            "synth-wiki" => Ok(DataSource::Lm(Corpus::synthetic(1_000_000, cfg.seed))),
            other => VisionDataset::by_name(other, cfg.seed)
                .map(DataSource::Vision)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset '{}'", other)),
        }
    }

    pub fn batch(&self, shape_x: &[usize], step: usize, eval_idx: Option<usize>) -> (HostTensor, HostTensor) {
        match self {
            DataSource::Vision(ds) => {
                let b = shape_x[0];
                let vb = match eval_idx {
                    Some(i) => ds.eval_batch(b, i),
                    None => ds.train_batch(b, step),
                };
                (
                    HostTensor::f32(shape_x, vb.x),
                    HostTensor::i32(&[b], vb.y),
                )
            }
            DataSource::Lm(c) => {
                let (b, s) = (shape_x[0], shape_x[1]);
                let lb = match eval_idx {
                    Some(i) => c.valid_batch(b, s, i),
                    None => c.train_batch(b, s, step),
                };
                (
                    HostTensor::i32(shape_x, lb.x),
                    HostTensor::i32(shape_x, lb.y),
                )
            }
        }
    }
}

/// One recorded step.
#[derive(Clone, Debug)]
pub struct StepMetric {
    pub step: usize,
    pub loss: f64,
    pub acc: f64,
    pub lr: f64,
    pub temperature: f64,
    /// effective active diagonals of layer 0 (DynaDiag only; Fig 8)
    pub effective_k: Option<usize>,
}

/// Aggregated evaluation.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    /// exp(loss) — perplexity for LM runs
    pub ppl: f64,
    /// per-example correctness, paired across methods by fixed eval seeds
    pub correct: Vec<bool>,
}

/// Outcome of one training run (one experiment cell).
pub struct TrainResult {
    pub cfg: RunConfig,
    pub history: Vec<StepMetric>,
    pub final_eval: EvalResult,
    /// final masks (masked methods; DynaDiag: finalized hard selection)
    pub masks: BTreeMap<String, Mask>,
    /// DynaDiag finalized diagonal matrices per layer
    pub finalized: Vec<(String, DiagMatrix)>,
    pub train_seconds: f64,
    pub store: ParamStore,
}

/// Periodic checkpointing policy for [`Trainer::train_checkpointed`].
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Write a checkpoint after every `every` completed steps (0 = never).
    pub every: usize,
    /// Directory receiving `ckpt_step{N:06}.ddck` files (created if absent).
    pub dir: PathBuf,
}

impl CheckpointSpec {
    /// Checkpoint path for a given step cursor.
    pub fn path_for_step(&self, next_step: usize) -> PathBuf {
        self.dir.join(format!("ckpt_step{:06}.ddck", next_step))
    }
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub session: Rc<Session>,
    train_exe: Rc<Artifact>,
    eval_exe: Rc<Artifact>,
    probe_exe: Option<Rc<Artifact>>,
    pub store: ParamStore,
    pub masks: BTreeMap<String, Mask>,
    method: Option<Box<dyn DstMethod>>,
    pub controller: Option<DynaDiagController>,
    pub data: DataSource,
    pub sparse_layers: Vec<(String, usize, usize)>,
    layer_sparsity: Vec<f64>,
    rng: Rng,
    is_lm: bool,
    /// First step the next `train` call executes (nonzero after a resume).
    start_step: usize,
    /// History recorded before the resume point.
    prior_history: Vec<StepMetric>,
    /// Wall seconds accumulated before the resume point.
    prior_seconds: f64,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> Result<Trainer> {
        let session = Session::open_kind(cfg.backend_kind()?, &cfg.artifacts_dir)?;
        Trainer::with_session(cfg, session)
    }

    /// Share one PJRT client + compile cache across runs (the experiment
    /// matrix compiles each artifact once).
    pub fn with_session(mut cfg: RunConfig, session: Rc<Session>) -> Result<Trainer> {
        let lm_model = cfg.model.starts_with("gpt");
        let lm_data = cfg.dataset == "synth-wiki";
        if cfg.dataset.is_empty() || lm_model != lm_data {
            cfg.dataset = RunConfig::infer_dataset(&cfg.model).to_string();
        }
        let param = if cfg.method.is_dynadiag() { "dynadiag" } else { "masked" };
        let train_name = format!("{}_{}_train", cfg.model, param);
        let eval_name = format!("{}_{}_eval", cfg.model, param);
        let train_exe = session
            .executable(&train_name)
            .with_context(|| format!("loading {}", train_name))?;
        let eval_exe = session.executable(&eval_name)?;

        let sparse_layers = train_exe.meta.sparse_layers()?;
        let shapes: Vec<LayerShape> = sparse_layers
            .iter()
            .map(|&(_, o, i)| LayerShape { n_out: o, n_in: i })
            .collect();
        // masked methods can go down to a handful of weights per layer;
        // DynaDiag's controller keeps its own one-whole-diagonal floor
        // (the paper's §5 caveat at extreme sparsity).
        let max_s = 1.0
            - 4.0 / shapes.iter().map(|l| l.n_in * l.n_out).max().unwrap_or(16) as f64;
        let layer_sparsity = allocate(cfg.distribution, &shapes, cfg.sparsity, max_s);

        let mut rng = Rng::new(cfg.seed);
        let mut method = dst::build_method(&cfg);
        let mut masks = BTreeMap::new();
        if !cfg.method.is_dynadiag() {
            for (idx, (name, o, i)) in sparse_layers.iter().enumerate() {
                let m = match &mut method {
                    Some(m) => m.init_mask(*o, *i, layer_sparsity[idx], &mut rng),
                    // Dense / Wanda: train dense
                    None => Mask::ones(*o, *i),
                };
                masks.insert(name.clone(), m);
            }
        }
        let probe_exe = match &method {
            Some(m) if m.needs_grads() => {
                Some(session.executable(&format!("{}_masked_gradprobe", cfg.model))?)
            }
            _ => None,
        };
        let controller = if cfg.method.is_dynadiag() {
            Some(DynaDiagController::new(&cfg, sparse_layers.clone()))
        } else {
            None
        };
        let store = ParamStore::init(&train_exe.meta, cfg.seed);
        let data = DataSource::for_run(&cfg)?;
        let is_lm = matches!(data, DataSource::Lm(_));
        Ok(Trainer {
            cfg,
            session,
            train_exe,
            eval_exe,
            probe_exe,
            store,
            masks,
            method,
            controller,
            data,
            sparse_layers,
            layer_sparsity,
            rng,
            is_lm,
            start_step: 0,
            prior_history: Vec::new(),
            prior_seconds: 0.0,
        })
    }

    /// Rebuild a trainer from a saved checkpoint and position it to resume
    /// at the checkpoint's step cursor. The run configuration comes from
    /// the checkpoint itself (resume never re-guesses hyperparameters);
    /// `train` then reproduces the uninterrupted run bit-for-bit
    /// (`rust/tests/determinism.rs` pins this).
    pub fn from_checkpoint(ckpt: TrainCheckpoint) -> Result<Trainer> {
        let mut t = Trainer::new(ckpt.cfg.clone())
            .context("rebuilding trainer from checkpoint config")?;
        // overwrite every piece of mutable training state with the
        // checkpointed values (Trainer::new freshly initialized them)
        t.store = ckpt.store;
        t.masks = ckpt.masks;
        t.rng = Rng::from_state(ckpt.rng.0, ckpt.rng.1, ckpt.rng.2);
        t.start_step = ckpt.next_step;
        t.prior_history = ckpt.history;
        t.prior_seconds = ckpt.train_seconds;
        Ok(t)
    }

    /// Snapshot the complete mutable training state at a step boundary
    /// into an owned [`TrainCheckpoint`] (clones the store — use
    /// [`Trainer::save_checkpoint`] on the hot path).
    /// `history` must hold exactly the metrics of steps `0..next_step`.
    pub fn checkpoint(
        &self,
        next_step: usize,
        history: &[StepMetric],
        seconds: f64,
    ) -> TrainCheckpoint {
        TrainCheckpoint {
            cfg: self.cfg.clone(),
            next_step,
            train_seconds: seconds,
            rng: self.rng.state(),
            store: self.store.clone(),
            masks: self.masks.clone(),
            history: history.to_vec(),
        }
    }

    /// Write a checkpoint to `path` without cloning any training state
    /// (the periodic hook runs inside the training loop; serialization
    /// borrows the store/masks/history directly).
    pub fn save_checkpoint(
        &self,
        path: &std::path::Path,
        next_step: usize,
        history: &[StepMetric],
        seconds: f64,
    ) -> Result<()> {
        let bytes = crate::artifact::checkpoint::encode_checkpoint(
            &self.cfg,
            next_step,
            seconds,
            self.rng.state(),
            &self.store,
            &self.masks,
            history,
        );
        crate::util::write_atomic(path, &bytes)
            .with_context(|| format!("saving checkpoint {}", path.display()))
    }

    fn batch_shape(meta: &crate::runtime::ArtifactMeta) -> Result<Vec<usize>> {
        Ok(meta
            .inputs
            .iter()
            .find(|s| s.name == "batch/x")
            .ok_or_else(|| anyhow::anyhow!("artifact has no batch/x"))?
            .shape
            .clone())
    }

    /// Assemble the train-step input list for `step`. The batch tensors
    /// are moved in (each appears exactly once in the spec list); every
    /// other tensor is drawn from the native workspace arena, so a loop
    /// that recycles its non-batch inputs after the step (see
    /// [`Trainer::train`]) allocates nothing in steady state.
    fn build_inputs(&self, step: usize, x: HostTensor, y: HostTensor) -> Result<Vec<HostTensor>> {
        let lr = lr_at(step, self.cfg.steps, self.cfg.warmup, self.cfg.lr, self.cfg.lr_min);
        let (mut x, mut y) = (Some(x), Some(y));
        let mut inputs = Vec::with_capacity(self.train_exe.meta.inputs.len());
        for spec in &self.train_exe.meta.inputs {
            let t = match spec.name.as_str() {
                "batch/x" => x.take().ok_or_else(|| anyhow::anyhow!("batch/x listed twice"))?,
                "batch/y" => y.take().ok_or_else(|| anyhow::anyhow!("batch/y listed twice"))?,
                "scalar/step" => workspace::tensor_scalar((step + 1) as f32),
                "scalar/lr" => workspace::tensor_scalar(lr as f32),
                "scalar/wd" => workspace::tensor_scalar(self.cfg.weight_decay as f32),
                "scalar/temp" => workspace::tensor_scalar(
                    self.controller.as_ref().unwrap().temperature(step) as f32,
                ),
                "scalar/l1" => workspace::tensor_scalar(
                    self.controller.as_ref().unwrap().l1_coeff() as f32,
                ),
                "kvec" => {
                    let kv = self.controller.as_ref().unwrap().kvec(step);
                    workspace::tensor_f32(&[kv.len()], kv)
                }
                name if name.starts_with("masks/") => {
                    let layer = &name["masks/".len()..];
                    let m = self
                        .masks
                        .get(layer)
                        .ok_or_else(|| anyhow::anyhow!("no mask for layer {}", layer))?;
                    let mut buf = workspace::take_uninit_f32(spec.shape.iter().product());
                    m.to_f32_into(&mut buf);
                    workspace::tensor_f32(&spec.shape, buf)
                }
                name => workspace::clone_tensor(self.store.get(name)?),
            };
            inputs.push(t);
        }
        Ok(inputs)
    }

    /// Run the grad-probe artifact, returning dense grads per sparse layer.
    fn grad_probe(&self, step: usize) -> Result<BTreeMap<String, Tensor>> {
        let probe = self.probe_exe.as_ref().expect("probe not loaded");
        let shape_x = Self::batch_shape(&probe.meta)?;
        let (x, y) = self.data.batch(&shape_x, step, None);
        let mut inputs = Vec::new();
        for spec in &probe.meta.inputs {
            let t = match spec.name.as_str() {
                "batch/x" => x.clone(),
                "batch/y" => y.clone(),
                name if name.starts_with("masks/") => {
                    let layer = &name["masks/".len()..];
                    HostTensor::f32(&spec.shape, self.masks[layer].to_f32())
                }
                name => self.store.get(name)?.clone(),
            };
            inputs.push(t);
        }
        let outputs = probe.run(&inputs)?;
        let mut grads = BTreeMap::new();
        for (name, out) in probe.meta.outputs.iter().zip(&outputs) {
            if let Some(layer) = name.strip_prefix("grad/") {
                let shape = out.shape().to_vec();
                grads.insert(
                    layer.to_string(),
                    Tensor::from_vec(&shape, out.as_f32()?.to_vec())?,
                );
            }
        }
        Ok(grads)
    }

    /// One topology update across all layers (masked methods).
    fn update_topology(&mut self, step: usize) -> Result<()> {
        let grads = match &self.method {
            Some(m) if m.needs_grads() => Some(self.grad_probe(step)?),
            _ => None,
        };
        let fraction = rigl_update_fraction(
            step,
            (self.cfg.update_until * self.cfg.steps as f64) as usize,
            self.cfg.update_frac,
        );
        if fraction <= 0.0 {
            return Ok(());
        }
        let layers = self.sparse_layers.clone();
        for (name, _, _) in &layers {
            let w_name = format!("params/{}/w", name);
            let w = self.store.tensor2(&w_name)?;
            let mask = self.masks[name].clone();
            let g = grads.as_ref().and_then(|g| g.get(name));
            let method = self.method.as_mut().unwrap();
            if method.is_static() {
                continue;
            }
            let up = method.update_layer(&mask, &w, g, fraction, &mut self.rng);
            debug_assert_eq!(up.mask.nnz(), mask.nnz(), "budget must be conserved");
            // re-init regrown weights + their optimizer moments
            if !up.grown.is_empty() {
                let cols = mask.cols;
                {
                    let wt = self.store.get_mut(&w_name)?.as_f32_mut()?;
                    for &(i, j) in &up.grown {
                        wt[i * cols + j] = match up.grow_action {
                            GrowAction::Zero => 0.0,
                            GrowAction::RandomSmall => self.rng.normal_f32(0.0, 0.01),
                            GrowAction::KeepValue => wt[i * cols + j],
                        };
                    }
                }
                self.store.zero_moments_at(&w_name, &up.grown)?;
            }
            self.masks.insert(name.clone(), up.mask);
        }
        Ok(())
    }

    /// Full training run.
    pub fn train(&mut self) -> Result<TrainResult> {
        self.train_checkpointed(None)
    }

    /// Full training run with optional periodic checkpointing. Checkpoints
    /// are written at step boundaries *after* that step's topology update,
    /// so the captured RNG stream and masks are exactly what the
    /// uninterrupted run carries into the next step. Resumed runs
    /// (see [`Trainer::from_checkpoint`]) continue from `start_step` with
    /// the prior history prepended.
    pub fn train_checkpointed(&mut self, ckpt: Option<&CheckpointSpec>) -> Result<TrainResult> {
        // ddlint: allow(clock) -- wall-clock of a whole training run, reported once
        let t0 = std::time::Instant::now();
        let prior_seconds = self.prior_seconds;
        let start_step = self.start_step;
        // consume the resume state: a second `train` call on the same
        // trainer starts from step 0 again (the pre-checkpoint behavior)
        self.start_step = 0;
        self.prior_seconds = 0.0;
        let shape_x = Self::batch_shape(&self.train_exe.meta)?;
        let mut history = std::mem::take(&mut self.prior_history);
        if history.len() != start_step {
            bail!(
                "resume state inconsistent: {} prior metrics for start step {}",
                history.len(),
                start_step
            );
        }
        history.reserve(self.cfg.steps.saturating_sub(history.len()));
        let loss_idx = self.train_exe.meta.output_index("loss")?;
        let acc_idx = self.train_exe.meta.output_index("acc")?;
        if let Some(spec) = ckpt {
            std::fs::create_dir_all(&spec.dir)
                .with_context(|| format!("creating checkpoint dir {}", spec.dir.display()))?;
        }

        for step in start_step..self.cfg.steps {
            let (x, y) = self.data.batch(&shape_x, step, None);
            let inputs = self.build_inputs(step, x, y)?;
            let mut outputs = self.train_exe.run(&inputs)?;
            // move params/opt outputs into the store, recycling the
            // superseded entries; then recycle every remaining pooled
            // buffer — with the native backend the steady-state loop
            // allocates nothing (see runtime::native::workspace). The
            // batch tensors are freshly allocated by the data pipeline
            // each step, so they are dropped rather than donated (the
            // arena would otherwise grow by two batch buffers per step).
            self.store.absorb_take(&self.train_exe.meta, &mut outputs);
            let loss = outputs[loss_idx].scalar()?;
            let acc = outputs[acc_idx].scalar()?;
            for t in outputs.drain(..) {
                workspace::give_tensor(t);
            }
            for (spec, t) in self.train_exe.meta.inputs.iter().zip(inputs) {
                if !spec.name.starts_with("batch/") {
                    workspace::give_tensor(t);
                }
            }
            if !loss.is_finite() {
                bail!("loss diverged at step {} ({})", step, loss);
            }
            let temperature = self
                .controller
                .as_ref()
                .map(|c| c.temperature(step))
                .unwrap_or(0.0);
            let effective_k = self.controller.as_ref().and_then(|c| {
                if step % 10 == 0 || step + 1 == self.cfg.steps {
                    let (name, _, _) = &self.sparse_layers[0];
                    let alpha = self
                        .store
                        .get(&format!("params/{}/alpha", name))
                        .ok()?
                        .as_f32()
                        .ok()?;
                    Some(c.effective_diagonals(0, alpha, step))
                } else {
                    None
                }
            });
            history.push(StepMetric {
                step,
                loss,
                acc,
                lr: lr_at(step, self.cfg.steps, self.cfg.warmup, self.cfg.lr, self.cfg.lr_min),
                temperature,
                effective_k,
            });

            if self.method.is_some() && dst::is_update_step(&self.cfg, step) {
                self.update_topology(step)?;
            }
            if let Some(spec) = ckpt {
                if spec.every > 0 && (step + 1) % spec.every == 0 && step + 1 < self.cfg.steps {
                    let path = spec.path_for_step(step + 1);
                    let seconds = prior_seconds + t0.elapsed().as_secs_f64();
                    self.save_checkpoint(&path, step + 1, &history, seconds)?;
                    crate::debug!("wrote checkpoint {}", path.display());
                }
            }
            if crate::util::log_enabled(3) && step % 50 == 0 {
                crate::debug!(
                    "{} {} S={:.2} step {}/{} loss {:.4}",
                    self.cfg.model,
                    self.cfg.method.name(),
                    self.cfg.sparsity,
                    step,
                    self.cfg.steps,
                    loss
                );
            }
        }

        // Wanda: one-shot prune after dense training
        if self.cfg.method == MethodKind::Wanda {
            for (idx, (name, _, _)) in self.sparse_layers.clone().iter().enumerate() {
                let w = self.store.tensor2(&format!("params/{}/w", name))?;
                let m = crate::dst::wanda::wanda_prune(&w, None, self.layer_sparsity[idx]);
                self.masks.insert(name.clone(), m);
            }
        }

        // DynaDiag finalization: hard TopK -> diagonal matrices + masks
        let mut finalized = Vec::new();
        let mut masks = self.masks.clone();
        if let Some(c) = &self.controller {
            for (l, (name, _, _)) in self.sparse_layers.iter().enumerate() {
                let alpha = self
                    .store
                    .get(&format!("params/{}/alpha", name))?
                    .as_f32()?
                    .to_vec();
                let v = self.store.tensor2(&format!("params/{}/v", name))?;
                let d = c.finalize_layer(l, &alpha, &v);
                masks.insert(name.clone(), d.to_mask());
                finalized.push((name.clone(), d));
            }
        }

        // DynaDiag is evaluated as the paper evaluates it: the *finalized*
        // hard top-K model (soft-TopK eval at very low T degenerates to a
        // single surviving diagonal per layer — see EXPERIMENTS.md §Perf).
        let final_eval = if self.controller.is_some() {
            let store = crate::train::lora::masked_store_from_dynadiag(
                &self.store,
                &finalized,
            )?;
            let ones: BTreeMap<String, Mask> = finalized
                .iter()
                .map(|(n, d)| (n.clone(), Mask::ones(d.n_out, d.n_in)))
                .collect();
            crate::train::lora::evaluate_masked(self, &store, &ones)?
        } else {
            self.evaluate()?
        };
        Ok(TrainResult {
            cfg: self.cfg.clone(),
            history,
            final_eval,
            masks,
            finalized,
            train_seconds: prior_seconds + t0.elapsed().as_secs_f64(),
            store: self.store.clone(),
        })
    }

    /// Evaluate on the held-out stream (fixed batches -> paired across runs).
    pub fn evaluate(&self) -> Result<EvalResult> {
        self.evaluate_with(&self.masks, &self.store)
    }

    /// Evaluation with explicit masks/store (Wanda, LoRA, ablations).
    pub fn evaluate_with(&self, masks: &BTreeMap<String, Mask>, store: &ParamStore) -> Result<EvalResult> {
        let shape_x = Self::batch_shape(&self.eval_exe.meta)?;
        let mut correct = Vec::new();
        let mut losses = Vec::new();
        for b in 0..self.cfg.eval_batches {
            let (x, y) = self.data.batch(&shape_x, 0, Some(b));
            let mut inputs = Vec::new();
            for spec in &self.eval_exe.meta.inputs {
                let t = match spec.name.as_str() {
                    "batch/x" => x.clone(),
                    "batch/y" => y.clone(),
                    "scalar/temp" => HostTensor::scalar_f32(
                        self.controller
                            .as_ref()
                            .map(|c| c.temperature(self.cfg.steps))
                            .unwrap_or(0.05) as f32,
                    ),
                    "kvec" => {
                        let kv = self.controller.as_ref().unwrap().kvec(self.cfg.steps);
                        HostTensor::f32(&[kv.len()], kv)
                    }
                    name if name.starts_with("masks/") => {
                        let layer = &name["masks/".len()..];
                        HostTensor::f32(&spec.shape, masks[layer].to_f32())
                    }
                    name => store.get(name)?.clone(),
                };
                inputs.push(t);
            }
            let mut outputs = self.eval_exe.run(&inputs)?;
            losses.push(outputs[0].scalar()?);
            if self.is_lm {
                // outputs: loss, loss_vec, correct token counts
                let seq = shape_x[1];
                for &c in outputs[2].as_i32()? {
                    // "correct" example := token accuracy above the byte-LM
                    // guess floor; fixed eval batches keep this paired
                    correct.push((c as usize) * 4 > seq);
                }
            } else {
                let preds = outputs[2].as_i32()?;
                for (p, t) in preds.iter().zip(y.as_i32()?) {
                    correct.push(p == t);
                }
            }
            // the native eval artifact builds its outputs from workspace
            // buffers; recycle them so repeated evals stay allocation-free
            for t in outputs.drain(..) {
                workspace::give_tensor(t);
            }
        }
        let loss = crate::util::mean(&losses);
        Ok(EvalResult {
            loss,
            accuracy: crate::stats::accuracy(&correct),
            ppl: loss.exp(),
            correct,
        })
    }
}
