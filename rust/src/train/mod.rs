//! L3 coordinator: the training loop over AOT artifacts, evaluation,
//! checkpoints, LoRA-FA fine-tuning, and the experiment-matrix runner.

pub mod lora;
pub mod state;
pub mod trainer;

pub use state::ParamStore;
pub use trainer::{
    CheckpointSpec, DataSource, EvalResult, StepMetric, TrainResult, Trainer,
};
