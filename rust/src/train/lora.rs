//! LoRA-FA fine-tuning of a DynaDiag-trained model (Sec 4.3.1 / Fig 5).
//!
//! Each sparse layer's effective weight becomes `W_diag + B·A` with A frozen
//! at random init (LoRA-FA freezes the down-projection; only B trains).
//! No dedicated artifact is needed: the masked grad-probe returns
//! d loss / d W_eff, and the chain rule gives dB = G·Aᵀ — the coordinator
//! composes W_eff on the host each step, uploads it through the masked
//! artifacts with all-ones masks, and Adam-updates B locally.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::runtime::HostTensor;
use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;
use crate::train::state::ParamStore;
use crate::train::trainer::{DataSource, EvalResult, Trainer};
use crate::util::rng::Rng;

/// One layer's LoRA-FA state.
pub struct LoraLayer {
    pub name: String,
    /// frozen sparse base (composed diagonal weight)
    pub base: Tensor,
    /// frozen down-projection A [r, n_in]
    pub a: Tensor,
    /// trained up-projection B [n_out, r]
    pub b: Tensor,
    m: Tensor,
    v: Tensor,
}

impl LoraLayer {
    fn w_eff(&self) -> Tensor {
        let delta = self.b.matmul(&self.a).expect("B@A");
        let mut w = self.base.clone();
        for (x, d) in w.data.iter_mut().zip(&delta.data) {
            *x += d;
        }
        w
    }

    /// Adam step on B from the dense grad of W_eff: dB = G · Aᵀ.
    fn update_b(&mut self, g: &Tensor, lr: f32, t: usize) {
        let db = g.matmul(&self.a.transpose2()).expect("G@At");
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let b1c = 1.0 - b1.powi(t as i32);
        let b2c = 1.0 - b2.powi(t as i32);
        for i in 0..self.b.data.len() {
            self.m.data[i] = b1 * self.m.data[i] + (1.0 - b1) * db.data[i];
            self.v.data[i] = b2 * self.v.data[i] + (1.0 - b2) * db.data[i] * db.data[i];
            let mh = self.m.data[i] / b1c;
            let vh = self.v.data[i] / b2c;
            self.b.data[i] -= lr * mh / (vh.sqrt() + eps);
        }
    }

    pub fn extra_params(&self) -> usize {
        self.a.len() + self.b.len()
    }

    /// Spatial spread of the fine-tuned delta (Fig 5b): fraction of matrix
    /// cells where |B·A| exceeds `thresh`·max — unstructured coverage.
    pub fn delta_coverage(&self, thresh: f32) -> f64 {
        let delta = self.b.matmul(&self.a).expect("B@A");
        let mx = delta.abs_max();
        if mx == 0.0 {
            return 0.0;
        }
        delta.data.iter().filter(|x| x.abs() > thresh * mx).count() as f64
            / delta.data.len() as f64
    }
}

/// Result of a LoRA-FA fine-tune.
pub struct LoraResult {
    pub rank: usize,
    pub eval: EvalResult,
    pub extra_params: usize,
    pub base_params: usize,
    pub coverage: f64,
}

/// Fine-tune a trained DynaDiag model's sparse layers at LoRA rank `r`.
///
/// `trainer` must be a DynaDiag trainer whose `train()` already ran;
/// `finalized` is the diagonal selection it produced.
pub fn lora_finetune(
    trainer: &Trainer,
    finalized: &[(String, crate::sparsity::diagonal::DiagMatrix)],
    store: &ParamStore,
    rank: usize,
    steps: usize,
    lr: f32,
) -> Result<LoraResult> {
    let cfg: &RunConfig = &trainer.cfg;
    let mut rng = Rng::new(cfg.seed ^ 0x10FA);
    // frozen bases from the finalized diagonals
    let mut layers: Vec<LoraLayer> = finalized
        .iter()
        .map(|(name, d)| {
            let base = d.to_dense();
            let (n_out, n_in) = (base.rows(), base.cols());
            LoraLayer {
                name: name.clone(),
                a: Tensor::randn(&[rank, n_in], (1.0 / n_in as f32).sqrt(), &mut rng),
                b: Tensor::zeros(&[n_out, rank]),
                m: Tensor::zeros(&[n_out, rank]),
                v: Tensor::zeros(&[n_out, rank]),
                base,
            }
        })
        .collect();

    // masked artifacts with all-ones masks carry W_eff
    let probe = trainer
        .session
        .executable(&format!("{}_masked_gradprobe", cfg.model))
        .context("LoRA needs the masked grad-probe artifact")?;
    let ones: BTreeMap<String, Mask> = layers
        .iter()
        .map(|l| (l.name.clone(), Mask::ones(l.base.rows(), l.base.cols())))
        .collect();

    // a masked-eval-compatible store: dynadiag store entries renamed
    let mut masked_store = masked_store_from_dynadiag(store, finalized)?;

    let shape_x = probe
        .meta
        .inputs
        .iter()
        .find(|s| s.name == "batch/x")
        .unwrap()
        .shape
        .clone();

    for t in 1..=steps {
        // refresh W_eff in the masked store
        for l in &layers {
            masked_store.set(&format!("params/{}/w", l.name), tensor_to_host(&l.w_eff()));
        }
        let (x, y) = trainer.data.batch(&shape_x, t, None);
        let mut inputs = Vec::new();
        for spec in &probe.meta.inputs {
            let tsr = match spec.name.as_str() {
                "batch/x" => x.clone(),
                "batch/y" => y.clone(),
                name if name.starts_with("masks/") => {
                    let layer = &name["masks/".len()..];
                    HostTensor::f32(&spec.shape, ones[layer].to_f32())
                }
                name => masked_store.get(name)?.clone(),
            };
            inputs.push(tsr);
        }
        let outputs = probe.run(&inputs)?;
        for (name, out) in probe.meta.outputs.iter().zip(&outputs) {
            if let Some(layer_name) = name.strip_prefix("grad/") {
                let g = Tensor::from_vec(out.shape(), out.as_f32()?.to_vec())?;
                if let Some(l) = layers.iter_mut().find(|l| l.name == layer_name) {
                    l.update_b(&g, lr, t);
                }
            }
        }
    }

    // final W_eff for evaluation
    for l in &layers {
        masked_store.set(&format!("params/{}/w", l.name), tensor_to_host(&l.w_eff()));
    }
    let eval = evaluate_masked(trainer, &masked_store, &ones)?;
    let extra: usize = layers.iter().map(|l| l.extra_params()).sum();
    let coverage = crate::util::mean(
        &layers.iter().map(|l| l.delta_coverage(0.05)).collect::<Vec<_>>(),
    );
    Ok(LoraResult {
        rank,
        eval,
        extra_params: extra,
        base_params: store.param_count(),
        coverage,
    })
}

fn tensor_to_host(t: &Tensor) -> HostTensor {
    HostTensor::f32(&t.shape, t.data.clone())
}

/// Build a masked-artifact store from a dynadiag store + finalized diagonals:
/// shared params copy over by name; sparse layers get w := composed diagonal.
pub fn masked_store_from_dynadiag(
    store: &ParamStore,
    finalized: &[(String, crate::sparsity::diagonal::DiagMatrix)],
) -> Result<ParamStore> {
    let mut out = ParamStore::default();
    let diag_names: std::collections::HashSet<&str> =
        finalized.iter().map(|(n, _)| n.as_str()).collect();
    for (name, t) in &store.entries {
        if !name.starts_with("params/") {
            continue;
        }
        let inner = &name["params/".len()..];
        // skip dynadiag-only leaves of sparse layers (v, alpha)
        let is_sparse_leaf = diag_names.iter().any(|d| {
            inner.starts_with(&format!("{}/", d))
        });
        if is_sparse_leaf && (inner.ends_with("/v") || inner.ends_with("/alpha")) {
            continue;
        }
        out.set(name, t.clone());
    }
    for (name, d) in finalized {
        out.set(&format!("params/{}/w", name), tensor_to_host(&d.to_dense()));
    }
    Ok(out)
}

/// Evaluate through the masked eval artifact with an explicit store/masks.
pub fn evaluate_masked(
    trainer: &Trainer,
    store: &ParamStore,
    masks: &BTreeMap<String, Mask>,
) -> Result<EvalResult> {
    let eval = trainer
        .session
        .executable(&format!("{}_masked_eval", trainer.cfg.model))?;
    let shape_x = eval
        .meta
        .inputs
        .iter()
        .find(|s| s.name == "batch/x")
        .unwrap()
        .shape
        .clone();
    let is_lm = matches!(trainer.data, DataSource::Lm(_));
    let mut correct = Vec::new();
    let mut losses = Vec::new();
    for bidx in 0..trainer.cfg.eval_batches {
        let (x, y) = trainer.data.batch(&shape_x, 0, Some(bidx));
        let mut inputs = Vec::new();
        for spec in &eval.meta.inputs {
            let t = match spec.name.as_str() {
                "batch/x" => x.clone(),
                "batch/y" => y.clone(),
                name if name.starts_with("masks/") => {
                    let layer = &name["masks/".len()..];
                    HostTensor::f32(&spec.shape, masks[layer].to_f32())
                }
                name => store.get(name)?.clone(),
            };
            inputs.push(t);
        }
        let outputs = eval.run(&inputs)?;
        losses.push(outputs[0].scalar()?);
        if is_lm {
            let seq = shape_x[1];
            for &c in outputs[2].as_i32()? {
                correct.push((c as usize) * 4 > seq);
            }
        } else {
            for (p, t) in outputs[2].as_i32()?.iter().zip(y.as_i32()?) {
                correct.push(p == t);
            }
        }
    }
    let loss = crate::util::mean(&losses);
    Ok(EvalResult {
        loss,
        accuracy: crate::stats::accuracy(&correct),
        ppl: loss.exp(),
        correct,
    })
}
