//! ParamStore — the host-side mirror of an artifact's parameter state.
//!
//! Holds every `params/` / `opt_m/` / `opt_v/` buffer between XLA steps and
//! routes them into/out of the executable by manifest name. Initialization
//! matches the L2 conventions (Xavier for matrices, 0.02·N(0,1) for
//! embeddings, ones for LN scale, zeros for biases/moments, 0.01·N(0,1)
//! for DynaDiag α).

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{ArtifactMeta, Dtype, HostTensor, IoSpec};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Named host tensors for one model.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub entries: BTreeMap<String, HostTensor>,
}

fn init_for(spec: &IoSpec, rng: &mut Rng) -> HostTensor {
    let n: usize = spec.shape.iter().product();
    let name = &spec.name;
    if spec.dtype == Dtype::I32 {
        return HostTensor::i32(&spec.shape, vec![0; n]);
    }
    let data: Vec<f32> = if name.starts_with("opt_m/") || name.starts_with("opt_v/") {
        vec![0.0; n]
    } else if name.ends_with("/g") {
        vec![1.0; n] // layernorm scale
    } else if name.ends_with("/b") {
        vec![0.0; n]
    } else if name.ends_with("/alpha") {
        // near-unit variance: the soft TopK is already selective at T ≈ 1,
        // so selected diagonals carry ᾱ ≈ 1 (not k/D) from step 0 — with a
        // tiny-variance init the min(k·softmax, 1) weights uniformly crush
        // every sparse layer by k/D and the model cannot train (§Perf log)
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    } else if name.contains("pos") || name.contains("tok_embed") {
        (0..n).map(|_| rng.normal_f32(0.0, 0.02)).collect()
    } else if spec.shape.len() >= 2 {
        let fan_out = spec.shape[0] as f32;
        let fan_in = spec.shape[spec.shape.len() - 1] as f32;
        let std = (2.0 / (fan_in + fan_out)).sqrt();
        (0..n).map(|_| rng.normal_f32(0.0, std)).collect()
    } else {
        vec![0.0; n]
    };
    HostTensor::f32(&spec.shape, data)
}

impl ParamStore {
    /// Initialize all stateful inputs (params + opt moments) of an artifact.
    pub fn init(meta: &ArtifactMeta, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed ^ 0x1417);
        let mut entries = BTreeMap::new();
        for spec in &meta.inputs {
            if spec.name.starts_with("params/")
                || spec.name.starts_with("opt_m/")
                || spec.name.starts_with("opt_v/")
            {
                entries.insert(spec.name.clone(), init_for(spec, &mut rng));
            }
        }
        ParamStore { entries }
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("param store has no '{}'", name))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut HostTensor> {
        self.entries
            .get_mut(name)
            .ok_or_else(|| anyhow!("param store has no '{}'", name))
    }

    pub fn set(&mut self, name: &str, t: HostTensor) {
        self.entries.insert(name.to_string(), t);
    }

    /// View a 2-D f32 param as a Tensor (copy).
    pub fn tensor2(&self, name: &str) -> Result<Tensor> {
        let t = self.get(name)?;
        Ok(Tensor::from_vec(t.shape(), t.as_f32()?.to_vec())?)
    }

    /// Absorb the outputs of a train step back into the store.
    pub fn absorb(&mut self, meta: &ArtifactMeta, outputs: &[HostTensor]) {
        for (name, out) in meta.outputs.iter().zip(outputs) {
            if self.entries.contains_key(name) {
                self.entries.insert(name.clone(), out.clone());
            }
        }
    }

    /// Allocation-free absorb: *move* the matching output tensors into the
    /// store (each is replaced by an empty placeholder in `outputs`) and
    /// recycle the superseded store entries into the native workspace
    /// arena. With the native backend this closes the buffer cycle — the
    /// steady-state train loop performs zero heap allocations; with the
    /// XLA backend it is simply a cheaper [`ParamStore::absorb`].
    pub fn absorb_take(&mut self, meta: &ArtifactMeta, outputs: &mut [HostTensor]) {
        for (name, out) in meta.outputs.iter().zip(outputs.iter_mut()) {
            if let Some(slot) = self.entries.get_mut(name) {
                let taken = std::mem::replace(
                    out,
                    HostTensor::F32 { shape: Vec::new(), data: Vec::new() },
                );
                let old = std::mem::replace(slot, taken);
                crate::runtime::native::workspace::give_tensor(old);
            }
        }
    }

    /// Zero the optimizer moments at specific coordinates of a layer
    /// (used when DST regrows connections — fresh moments for fresh links).
    pub fn zero_moments_at(&mut self, layer_w: &str, coords: &[(usize, usize)]) -> Result<()> {
        let cols = {
            let w = self.get(layer_w)?;
            w.shape()[1]
        };
        for prefix in ["opt_m/", "opt_v/"] {
            let name = format!("{}{}", prefix, &layer_w["params/".len()..]);
            if let Ok(t) = self.get_mut(&name) {
                let data = t.as_f32_mut()?;
                for &(i, j) in coords {
                    data[i * cols + j] = 0.0;
                }
            }
        }
        Ok(())
    }

    /// Total parameter count (params/ section only).
    pub fn param_count(&self) -> usize {
        self.entries
            .iter()
            .filter(|(k, _)| k.starts_with("params/"))
            .map(|(_, v)| v.len())
            .sum()
    }

    // -- checkpointing -------------------------------------------------------

    /// Serialize to a `DDIAG` param-store container (versioned, per-section
    /// CRC32, atomic rename-into-place — see [`crate::artifact`]). The
    /// payload codec is shared with the full training checkpoint
    /// ([`crate::artifact::checkpoint`]).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        use crate::artifact::{Enc, Kind, SectionWriter};
        let mut e = Enc::new();
        crate::artifact::checkpoint::encode_store(self, &mut e);
        let mut w = SectionWriter::new(Kind::Store);
        w.section("store", &e.buf);
        w.finish_to(path)
    }

    /// Load a store written by [`ParamStore::save`]. Rejects truncated,
    /// corrupted, version-mismatched, or wrong-kind files with an
    /// actionable error.
    pub fn load(path: &std::path::Path) -> Result<ParamStore> {
        use crate::artifact::{ArtifactFile, Dec, Kind};
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading param store {}", path.display()))?;
        let f = ArtifactFile::parse(&bytes, Kind::Store)
            .with_context(|| format!("loading param store {}", path.display()))?;
        let mut d = Dec::new(f.section("store")?, "store");
        let store = crate::artifact::checkpoint::decode_store(&mut d)?;
        d.expect_end()?;
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn fake_meta() -> ArtifactMeta {
        ArtifactMeta {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            inputs: vec![
                IoSpec { name: "params/blocks/0/fc1/w".into(), shape: vec![4, 8], dtype: Dtype::F32 },
                IoSpec { name: "params/blocks/0/fc1/b".into(), shape: vec![4], dtype: Dtype::F32 },
                IoSpec { name: "params/ln_f/g".into(), shape: vec![8], dtype: Dtype::F32 },
                IoSpec { name: "params/blocks/0/fc1/alpha".into(), shape: vec![8], dtype: Dtype::F32 },
                IoSpec { name: "opt_m/blocks/0/fc1/w".into(), shape: vec![4, 8], dtype: Dtype::F32 },
                IoSpec { name: "batch/x".into(), shape: vec![2, 8], dtype: Dtype::F32 },
            ],
            outputs: vec!["params/blocks/0/fc1/w".into(), "loss".into()],
            meta: Json::Null,
        }
    }

    #[test]
    fn init_conventions() {
        let store = ParamStore::init(&fake_meta(), 1);
        assert_eq!(store.entries.len(), 5, "batch must not be stored");
        let w = store.get("params/blocks/0/fc1/w").unwrap().as_f32().unwrap();
        assert!(w.iter().any(|&x| x != 0.0));
        let b = store.get("params/blocks/0/fc1/b").unwrap().as_f32().unwrap();
        assert!(b.iter().all(|&x| x == 0.0));
        let g = store.get("params/ln_f/g").unwrap().as_f32().unwrap();
        assert!(g.iter().all(|&x| x == 1.0));
        let m = store.get("opt_m/blocks/0/fc1/w").unwrap().as_f32().unwrap();
        assert!(m.iter().all(|&x| x == 0.0));
        let a = store.get("params/blocks/0/fc1/alpha").unwrap().as_f32().unwrap();
        // near-unit-variance init (see init_for comment)
        assert!(a.iter().any(|&x| x.abs() > 0.3));
        assert!(a.iter().all(|&x| x.abs() < 6.0));
    }

    #[test]
    fn absorb_routes_by_name() {
        let meta = fake_meta();
        let mut store = ParamStore::init(&meta, 1);
        let new_w = HostTensor::f32(&[4, 8], vec![7.0; 32]);
        store.absorb(&meta, &[new_w, HostTensor::scalar_f32(1.0)]);
        assert_eq!(store.get("params/blocks/0/fc1/w").unwrap().as_f32().unwrap()[0], 7.0);
    }

    #[test]
    fn absorb_take_moves_and_leaves_placeholders() {
        let meta = fake_meta();
        let mut store = ParamStore::init(&meta, 1);
        let mut outputs = vec![
            HostTensor::f32(&[4, 8], vec![9.0; 32]),
            HostTensor::scalar_f32(1.0),
        ];
        store.absorb_take(&meta, &mut outputs);
        assert_eq!(store.get("params/blocks/0/fc1/w").unwrap().as_f32().unwrap()[0], 9.0);
        // the absorbed slot becomes an empty placeholder; the loss stays
        assert!(outputs[0].is_empty());
        assert_eq!(outputs[1].scalar().unwrap(), 1.0);
    }

    #[test]
    fn zero_moments() {
        let meta = fake_meta();
        let mut store = ParamStore::init(&meta, 1);
        store
            .get_mut("opt_m/blocks/0/fc1/w")
            .unwrap()
            .as_f32_mut()
            .unwrap()
            .fill(5.0);
        store
            .zero_moments_at("params/blocks/0/fc1/w", &[(1, 2), (3, 7)])
            .unwrap();
        let m = store.get("opt_m/blocks/0/fc1/w").unwrap().as_f32().unwrap();
        assert_eq!(m[1 * 8 + 2], 0.0);
        assert_eq!(m[3 * 8 + 7], 0.0);
        assert_eq!(m[0], 5.0);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let store = ParamStore::init(&fake_meta(), 3);
        let path = std::env::temp_dir().join("dynadiag_ckpt_test.bin");
        store.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(store.entries.len(), loaded.entries.len());
        for (k, v) in &store.entries {
            let l = loaded.get(k).unwrap();
            assert_eq!(v.shape(), l.shape());
            assert_eq!(v.as_f32().unwrap(), l.as_f32().unwrap());
        }
    }
}
