//! Diagonal sparsity algebra (Sec 3.1 + Apdx A/B of the paper).
//!
//! Conventions mirror `python/compile/kernels/ref.py` exactly:
//! a weight matrix is `[n_out, n_in]`; candidate diagonal `off ∈ [0, n_in)`
//! owns entries `(i, (i + off) mod n_in)` for `i ∈ [0, n_out)`; every matrix
//! element belongs to exactly one candidate diagonal (`off = (j - i) mod
//! n_in`), so selecting K of the n_in candidates gives density `K / n_in`.

use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// A diagonal-sparse matrix: selected offsets + offset-major values.
///
/// Diagonal `off` owns entries `(i, (i + off) mod n_in)`, so a matrix with
/// K selected diagonals stores `K · n_out` values:
///
/// ```
/// use dynadiag::sparsity::diagonal::DiagMatrix;
/// use dynadiag::tensor::Tensor;
///
/// let mut d = DiagMatrix::new(3, 3, vec![1]); // one wrapped superdiagonal
/// for i in 0..3 {
///     d.values[0][i] = (i + 1) as f32;
/// }
/// let w = d.to_dense();
/// assert_eq!(w.at2(0, 1), 1.0); // row 0 owns column (0+1) mod 3
/// assert_eq!(w.at2(2, 0), 3.0); // row 2 wraps to column (2+1) mod 3
/// assert_eq!(d.nnz(), 3);
/// assert!((d.sparsity() - 2.0 / 3.0).abs() < 1e-12);
///
/// // y = x @ W.T computed diagonal-wise matches the dense product
/// let x = Tensor::ones(&[1, 3]);
/// assert_eq!(d.matmul_t(&x).unwrap().data, w.matmul_t(&x).unwrap().data);
/// ```
#[derive(Clone, Debug)]
pub struct DiagMatrix {
    pub n_out: usize,
    pub n_in: usize,
    /// selected diagonal offsets, each in [0, n_in)
    pub offsets: Vec<usize>,
    /// values[j][i] = entry of diagonal offsets[j] at row i; len n_out each
    pub values: Vec<Vec<f32>>,
}

/// Number of diagonals for a target sparsity (footnote 1 of the paper,
/// restated for our per-element-partition convention): K = (1-S)·n_in.
///
/// ```
/// use dynadiag::sparsity::diagonal::diag_count;
/// assert_eq!(diag_count(768, 0.9), 77);   // 90% sparse keeps ~10% of diagonals
/// assert_eq!(diag_count(768, 0.0), 768);  // dense keeps all of them
/// assert_eq!(diag_count(768, 0.9999), 1); // never below one diagonal
/// ```
pub fn diag_count(n_in: usize, sparsity: f64) -> usize {
    (((1.0 - sparsity) * n_in as f64).round() as usize).clamp(1, n_in)
}

/// Which candidate diagonal owns element (i, j).
#[inline]
pub fn owner_offset(i: usize, j: usize, n_in: usize) -> usize {
    (j + n_in - (i % n_in)) % n_in
}

/// Column of diagonal `off` at row `i`.
#[inline]
pub fn diag_col(i: usize, off: usize, n_in: usize) -> usize {
    (i + off) % n_in
}

impl DiagMatrix {
    pub fn new(n_out: usize, n_in: usize, offsets: Vec<usize>) -> DiagMatrix {
        let values = vec![vec![0.0; n_out]; offsets.len()];
        DiagMatrix { n_out, n_in, offsets, values }
    }

    pub fn k(&self) -> usize {
        self.offsets.len()
    }

    pub fn nnz(&self) -> usize {
        self.k() * self.n_out
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.n_out * self.n_in) as f64
    }

    /// Materialize to a dense tensor (mirror of ref.compose_dense).
    pub fn to_dense(&self) -> Tensor {
        let mut w = Tensor::zeros(&[self.n_out, self.n_in]);
        for (j, &off) in self.offsets.iter().enumerate() {
            for i in 0..self.n_out {
                *w.at2_mut(i, diag_col(i, off, self.n_in)) = self.values[j][i];
            }
        }
        w
    }

    /// Binary mask of the selected diagonals.
    pub fn to_mask(&self) -> Mask {
        let mut m = Mask::zeros(self.n_out, self.n_in);
        for &off in &self.offsets {
            for i in 0..self.n_out {
                m.set(i, diag_col(i, off, self.n_in), true);
            }
        }
        m
    }

    /// Extract a diagonal matrix from a dense W given the selected offsets.
    pub fn from_dense(w: &Tensor, offsets: Vec<usize>) -> Result<DiagMatrix> {
        if w.rank() != 2 {
            bail!("from_dense wants 2-D, got {:?}", w.shape);
        }
        let (n_out, n_in) = (w.rows(), w.cols());
        let mut d = DiagMatrix::new(n_out, n_in, offsets);
        for j in 0..d.k() {
            let off = d.offsets[j];
            for i in 0..n_out {
                d.values[j][i] = w.at2(i, diag_col(i, off, n_in));
            }
        }
        Ok(d)
    }

    /// `y = x @ W.T` — host mirror of the L1 Pallas kernel (used for golden
    /// checks and the measured CPU path of Fig 7 / Table 8).
    pub fn matmul_t(&self, x: &Tensor) -> Result<Tensor> {
        if x.rank() != 2 || x.cols() != self.n_in {
            bail!("diag matmul_t: x {:?} vs n_in {}", x.shape, self.n_in);
        }
        let b = x.rows();
        let mut y = Tensor::zeros(&[b, self.n_out]);
        for (j, &off) in self.offsets.iter().enumerate() {
            let vals = &self.values[j];
            for bi in 0..b {
                let xrow = &x.data[bi * self.n_in..(bi + 1) * self.n_in];
                let yrow = &mut y.data[bi * self.n_out..(bi + 1) * self.n_out];
                for i in 0..self.n_out {
                    yrow[i] += vals[i] * xrow[diag_col(i, off, self.n_in)];
                }
            }
        }
        Ok(y)
    }

    /// `dx = dy @ W` — the transposed product, still diagonal-wise (Apdx A).
    pub fn matmul(&self, dy: &Tensor) -> Result<Tensor> {
        if dy.rank() != 2 || dy.cols() != self.n_out {
            bail!("diag matmul: dy {:?} vs n_out {}", dy.shape, self.n_out);
        }
        let b = dy.rows();
        let mut dx = Tensor::zeros(&[b, self.n_in]);
        for (j, &off) in self.offsets.iter().enumerate() {
            let vals = &self.values[j];
            for bi in 0..b {
                let dyrow = &dy.data[bi * self.n_out..(bi + 1) * self.n_out];
                let dxrow = &mut dx.data[bi * self.n_in..(bi + 1) * self.n_in];
                for i in 0..self.n_out {
                    dxrow[diag_col(i, off, self.n_in)] += vals[i] * dyrow[i];
                }
            }
        }
        Ok(dx)
    }

    /// Transpose: by the Apdx A theorem the result is again diagonal-sparse
    /// (over n_out candidate offsets). Only exact when n_out % n_in == 0 or
    /// n_in % n_out == 0, which holds for every transformer layer we build.
    pub fn transpose(&self) -> Result<DiagMatrix> {
        let m = self.to_mask().transpose();
        let w = self.to_dense().transpose2();
        // discover the offsets of the transposed pattern
        let mut offs: Vec<usize> = Vec::new();
        let mut seen = vec![false; w.cols()];
        for i in 0..m.rows {
            for j in 0..m.cols {
                if m.get(i, j) {
                    let off = owner_offset(i, j, w.cols());
                    if !seen[off] {
                        seen[off] = true;
                        offs.push(off);
                    }
                }
            }
        }
        offs.sort_unstable();
        let d = DiagMatrix::from_dense(&w, offs)?;
        // verify we reproduced every nonzero (i.e. pattern is truly diagonal)
        if d.to_mask() != m {
            bail!(
                "transpose of {}x{} K={} is not diagonal-expressible",
                self.n_out,
                self.n_in,
                self.k()
            );
        }
        Ok(d)
    }

    /// Per-diagonal mean |value| — the magnitude score DiagHeur prunes by.
    pub fn diag_magnitudes(&self) -> Vec<f32> {
        self.values
            .iter()
            .map(|v| v.iter().map(|x| x.abs()).sum::<f32>() / v.len() as f32)
            .collect()
    }
}

/// Build the mask of K selected diagonals (used by DiagHeur + finalization).
pub fn diag_mask(n_out: usize, n_in: usize, offsets: &[usize]) -> Mask {
    let mut m = Mask::zeros(n_out, n_in);
    for &off in offsets {
        for i in 0..n_out {
            m.set(i, diag_col(i, off, n_in), true);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, forall_explain};
    use crate::util::rng::Rng;

    fn random_diag(rng: &mut Rng, n_out: usize, n_in: usize, k: usize) -> DiagMatrix {
        let offsets = rng.choose_k(n_in, k);
        let mut d = DiagMatrix::new(n_out, n_in, offsets);
        for j in 0..d.k() {
            for i in 0..n_out {
                d.values[j][i] = rng.normal_f32(0.0, 1.0);
            }
        }
        d
    }

    #[test]
    fn dense_roundtrip() {
        forall(
            10,
            40,
            |r| {
                let n_in = 2 + r.below(14);
                let n_out = 2 + r.below(20);
                let k = 1 + r.below(n_in);
                let mut rr = r.fork(3);
                random_diag(&mut rr, n_out, n_in, k)
            },
            |d| {
                let w = d.to_dense();
                let d2 = DiagMatrix::from_dense(&w, d.offsets.clone()).unwrap();
                d2.to_dense() == w && w.nnz() <= d.nnz()
            },
        );
    }

    #[test]
    fn matmul_t_matches_dense() {
        forall_explain(
            11,
            40,
            |r| {
                let n_in = 2 + r.below(12);
                let n_out = 2 + r.below(16);
                let k = 1 + r.below(n_in);
                let b = 1 + r.below(4);
                let mut rr = r.fork(5);
                let d = random_diag(&mut rr, n_out, n_in, k);
                let x = Tensor::randn(&[b, n_in], 1.0, &mut rr);
                (d, x)
            },
            |(d, x)| {
                let fast = d.matmul_t(x).unwrap();
                let slow = d.to_dense().matmul_t(x).unwrap();
                let diff = fast.max_abs_diff(&slow);
                if diff < 1e-4 {
                    Ok(())
                } else {
                    Err(format!("diff {}", diff))
                }
            },
        );
    }

    #[test]
    fn matmul_matches_dense_transpose_product() {
        forall_explain(
            12,
            40,
            |r| {
                let n_in = 2 + r.below(12);
                let n_out = 2 + r.below(16);
                let k = 1 + r.below(n_in);
                let b = 1 + r.below(4);
                let mut rr = r.fork(7);
                let d = random_diag(&mut rr, n_out, n_in, k);
                let dy = Tensor::randn(&[b, n_out], 1.0, &mut rr);
                (d, dy)
            },
            |(d, dy)| {
                let fast = d.matmul(dy).unwrap();
                let slow = dy.matmul(&d.to_dense()).unwrap();
                let diff = fast.max_abs_diff(&slow);
                if diff < 1e-4 {
                    Ok(())
                } else {
                    Err(format!("diff {}", diff))
                }
            },
        );
    }

    /// Apdx A: transposition preserves pseudo-diagonality.  In our
    /// max-length-diagonal convention this is exact whenever n_in | n_out
    /// (square matrices and fc1-shaped layers); the other orientation never
    /// materializes a transposed pattern — `matmul` computes dy @ W
    /// diagonal-wise directly, like the Pallas t-kernel.
    #[test]
    fn transpose_invariance_divisible_dims() {
        forall_explain(
            13,
            60,
            |r| {
                let base = 2 + r.below(8);
                let mult = 1 + r.below(4);
                let (n_out, n_in) = (base * mult, base);
                let k = 1 + r.below(n_in);
                let mut rr = r.fork(11);
                random_diag(&mut rr, n_out, n_in, k)
            },
            |d| {
                let t = d.transpose().map_err(|e| e.to_string())?;
                let want = d.to_dense().transpose2();
                if t.to_dense() == want {
                    Ok(())
                } else {
                    Err("transpose values mismatch".into())
                }
            },
        );
    }

    /// Apdx B Lemma 1: any k >= 1 diagonals give full row coverage, and full
    /// column coverage when n_out >= n_in.
    #[test]
    fn coverage_lemma() {
        forall(
            14,
            60,
            |r| {
                let n_in = 2 + r.below(12);
                let n_out = n_in + r.below(12);
                let k = 1 + r.below(n_in);
                let mut rr = r.fork(13);
                random_diag(&mut rr, n_out, n_in, k)
            },
            |d| d.to_mask().full_coverage(),
        );
    }

    /// Apdx B rank argument: random diagonal matrices achieve full rank
    /// min(n_out, n_in) almost surely once k is moderate.
    #[test]
    fn rank_preservation() {
        let mut rng = Rng::new(15);
        for &(n, k) in &[(8usize, 3usize), (12, 4), (16, 2)] {
            let d = random_diag(&mut rng, n, n, k);
            // k>=2 distinct wrapped diagonals on a square matrix: full rank
            // with probability 1 for continuous values.
            assert_eq!(d.to_dense().matrix_rank(1e-6), n, "n={} k={}", n, k);
        }
    }

    #[test]
    fn diag_count_budget() {
        assert_eq!(diag_count(768, 0.9), 77);
        assert_eq!(diag_count(768, 0.0), 768);
        assert_eq!(diag_count(768, 0.9999), 1);
        // nnz matches (1-S) * total within one diagonal
        let k = diag_count(128, 0.8);
        let nnz = k * 256; // n_out = 256
        let want = 0.2 * (256.0 * 128.0);
        assert!((nnz as f64 - want).abs() <= 256.0);
    }

    #[test]
    fn owner_offset_partition() {
        // every element owned by exactly one diagonal
        let (n_out, n_in) = (6, 4);
        for i in 0..n_out {
            for j in 0..n_in {
                let off = owner_offset(i, j, n_in);
                assert_eq!(diag_col(i, off, n_in), j);
            }
        }
    }
}
