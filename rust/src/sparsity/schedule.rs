//! Training-time schedules the coordinator drives (Sec 3.2, Tables 14/15,
//! Fig 8): temperature annealing, sparsity ramps, RigL-style update-fraction
//! decay, and the LR schedule with warmup.

/// Shape of a schedule curve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Curve {
    Constant,
    Linear,
    Cosine,
}

impl Curve {
    pub fn parse(s: &str) -> Option<Curve> {
        match s {
            "constant" => Some(Curve::Constant),
            "linear" => Some(Curve::Linear),
            "cosine" => Some(Curve::Cosine),
            _ => None,
        }
    }

    /// Canonical name, round-trippable through [`Curve::parse`]
    /// (checkpoint serialization relies on this).
    pub fn name(self) -> &'static str {
        match self {
            Curve::Constant => "constant",
            Curve::Linear => "linear",
            Curve::Cosine => "cosine",
        }
    }

    /// Interpolation factor in [0, 1]: 0 at t=0 -> 1 at t=1.
    fn frac(self, t: f64) -> f64 {
        let t = t.clamp(0.0, 1.0);
        match self {
            Curve::Constant => 1.0,
            Curve::Linear => t,
            Curve::Cosine => 0.5 * (1.0 - (std::f64::consts::PI * t).cos()),
        }
    }
}

/// A value annealed from `start` to `end` over `total_steps`.
#[derive(Clone, Copy, Debug)]
pub struct Schedule {
    pub curve: Curve,
    pub start: f64,
    pub end: f64,
    pub total_steps: usize,
}

impl Schedule {
    pub fn new(curve: Curve, start: f64, end: f64, total_steps: usize) -> Self {
        Schedule { curve, start, end, total_steps: total_steps.max(1) }
    }

    pub fn at(&self, step: usize) -> f64 {
        let t = step as f64 / self.total_steps as f64;
        self.start + (self.end - self.start) * self.curve.frac(t)
    }
}

/// Temperature schedule for the soft TopK (Fig 8): high T -> exploration,
/// annealed toward `t_final` -> exploitation.
pub fn temperature(curve: Curve, step: usize, total: usize, t0: f64, t_final: f64) -> f64 {
    match curve {
        // Constant = target sparsity enforced from step 0 (no exploration)
        Curve::Constant => t_final,
        c => Schedule::new(c, t0, t_final, total).at(step),
    }
}

/// Sparsity ramp (Table 15): anneal the *enforced* sparsity from dense-ish
/// to the target, constant = full target sparsity from step 0.
pub fn sparsity_at(curve: Curve, step: usize, total: usize, s_init: f64, s_target: f64) -> f64 {
    match curve {
        Curve::Constant => s_target,
        c => Schedule::new(c, s_init, s_target, total).at(step),
    }
}

/// RigL Eq. (1): update fraction cosine-decayed to zero by `t_end`.
pub fn rigl_update_fraction(step: usize, t_end: usize, alpha0: f64) -> f64 {
    if step >= t_end {
        return 0.0;
    }
    let t = step as f64 / t_end as f64;
    alpha0 / 2.0 * (1.0 + (std::f64::consts::PI * t).cos())
}

/// Cosine LR with linear warmup (Apdx C recipes).
pub fn lr_at(step: usize, total: usize, warmup: usize, lr_max: f64, lr_min: f64) -> f64 {
    if warmup > 0 && step < warmup {
        return lr_max * (step + 1) as f64 / warmup as f64;
    }
    let t = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
    lr_min + 0.5 * (lr_max - lr_min) * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_hit_endpoints() {
        for curve in [Curve::Linear, Curve::Cosine] {
            let s = Schedule::new(curve, 10.0, 1.0, 100);
            assert!((s.at(0) - 10.0).abs() < 1e-9);
            assert!((s.at(100) - 1.0).abs() < 1e-9);
            // monotone decreasing for start > end
            let mut prev = f64::INFINITY;
            for step in 0..=100 {
                let v = s.at(step);
                assert!(v <= prev + 1e-12);
                prev = v;
            }
        }
    }

    #[test]
    fn constant_is_flat_at_target() {
        assert_eq!(temperature(Curve::Constant, 0, 100, 10.0, 0.5), 0.5);
        assert_eq!(sparsity_at(Curve::Constant, 0, 100, 0.5, 0.9), 0.9);
    }

    #[test]
    fn cosine_slower_than_linear_early() {
        // cosine holds the high value longer early on (more exploration)
        let lin = temperature(Curve::Linear, 10, 100, 10.0, 0.5);
        let cos = temperature(Curve::Cosine, 10, 100, 10.0, 0.5);
        assert!(cos > lin);
    }

    #[test]
    fn rigl_fraction_decays_to_zero() {
        assert!((rigl_update_fraction(0, 1000, 0.3) - 0.3).abs() < 1e-9);
        let mid = rigl_update_fraction(500, 1000, 0.3);
        assert!((mid - 0.15).abs() < 1e-9);
        assert_eq!(rigl_update_fraction(1000, 1000, 0.3), 0.0);
        assert_eq!(rigl_update_fraction(2000, 1000, 0.3), 0.0);
    }

    #[test]
    fn lr_warmup_then_cosine() {
        let lr0 = lr_at(0, 100, 10, 1e-3, 1e-5);
        assert!(lr0 < 1e-3 / 5.0);
        let peak = lr_at(10, 100, 10, 1e-3, 1e-5);
        assert!((peak - 1e-3).abs() < 1e-4);
        let end = lr_at(100, 100, 10, 1e-3, 1e-5);
        assert!((end - 1e-5).abs() < 1e-6);
    }
}
