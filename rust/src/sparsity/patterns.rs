//! Structured mask generators for the baselines (Fig 2's pattern zoo):
//! N:M (SRigL), block (DSB), butterfly (Pixelated Butterfly), plus random
//! unstructured init used by SET/MEST/RigL.

use crate::sparsity::mask::Mask;
use crate::util::rng::Rng;

/// N:M pattern: in every group of `m` consecutive weights along the input
/// dim, exactly `n` are active. `scores` (same layout as the matrix) picks
/// which; random when None.
pub fn nm_mask(
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    scores: Option<&[f32]>,
    rng: &mut Rng,
) -> Mask {
    assert!(n <= m && m > 0);
    let mut mask = Mask::zeros(rows, cols);
    for i in 0..rows {
        let mut j = 0;
        while j < cols {
            let g = (cols - j).min(m);
            let keep = ((n as f64 / m as f64) * g as f64).round() as usize;
            let keep = keep.max(if g > 0 { 1 } else { 0 }).min(g);
            let mut idx: Vec<usize> = (0..g).collect();
            match scores {
                Some(s) => idx.sort_by(|&a, &b| {
                    s[i * cols + j + b]
                        .abs()
                        .partial_cmp(&s[i * cols + j + a].abs())
                        .unwrap()
                }),
                None => rng.shuffle(&mut idx),
            }
            for &t in idx.iter().take(keep) {
                mask.set(i, j + t, true);
            }
            j += g;
        }
    }
    mask
}

/// Choose (n, m) for a target sparsity with fixed m: n = round((1-S)·m).
pub fn nm_for_sparsity(m: usize, sparsity: f64) -> (usize, usize) {
    let n = (((1.0 - sparsity) * m as f64).round() as usize).clamp(1, m);
    (n, m)
}

/// Block-sparse mask: `bs × bs` blocks, `active` of them on, chosen by
/// block scores (mean |w| per block) or randomly.
pub fn block_mask(
    rows: usize,
    cols: usize,
    bs: usize,
    active: usize,
    block_scores: Option<&[f32]>,
    rng: &mut Rng,
) -> Mask {
    let nbr = rows.div_ceil(bs);
    let nbc = cols.div_ceil(bs);
    let total = nbr * nbc;
    let active = active.min(total);
    let chosen: Vec<usize> = match block_scores {
        Some(s) => {
            assert_eq!(s.len(), total);
            crate::util::top_k_indices(s, active)
        }
        None => rng.choose_k(total, active),
    };
    let mut mask = Mask::zeros(rows, cols);
    for b in chosen {
        let (br, bc) = (b / nbc, b % nbc);
        for i in br * bs..((br + 1) * bs).min(rows) {
            for j in bc * bs..((bc + 1) * bs).min(cols) {
                mask.set(i, j, true);
            }
        }
    }
    mask
}

/// Number of active blocks for a target sparsity.
pub fn blocks_for_sparsity(rows: usize, cols: usize, bs: usize, sparsity: f64) -> usize {
    let total = rows.div_ceil(bs) * cols.div_ceil(bs);
    (((1.0 - sparsity) * total as f64).round() as usize).clamp(1, total)
}

/// Fixed butterfly mask (Pixelated Butterfly, simplified): the union of
/// log2(n) butterfly factors' support, rendered at block granularity `bs`,
/// then thinned to the sparsity budget by keeping the lowest-stride stripes.
///
/// The butterfly support at stage s connects index pairs differing in bit s;
/// at block level this is a block-diagonal-of-stride-2^s pattern — exactly
/// the "flat butterfly" structure PBFly trains with.
pub fn butterfly_mask(rows: usize, cols: usize, bs: usize, sparsity: f64) -> Mask {
    let mut mask = Mask::zeros(rows, cols);
    let nbr = rows.div_ceil(bs);
    let nbc = cols.div_ceil(bs);
    let nb = nbr.max(nbc);
    let budget = (((1.0 - sparsity) * (nbr * nbc) as f64).round() as usize).max(1);

    // stage-0 stripes = block diagonal; each next stage adds blocks at
    // stride 2^s off the diagonal (wrapped), like a flattened butterfly.
    let mut placed = 0usize;
    let mut on = vec![false; nbr * nbc];
    'outer: for stage in 0..=nb.ilog2() as usize + 1 {
        let stride = 1usize << stage;
        for d in 0..nbr.max(nbc) {
            for &sgn in &[0usize, 1] {
                // wrap both above and below the diagonal
                let br = d % nbr;
                let shift = if sgn == 0 { stride - 1 } else { nbc.saturating_sub(stride - 1) };
                let bc = (d + shift) % nbc;
                let idx = br * nbc + bc;
                if !on[idx] {
                    on[idx] = true;
                    placed += 1;
                    if placed >= budget {
                        break 'outer;
                    }
                }
                if stage == 0 {
                    break; // diagonal has no sign
                }
            }
        }
    }
    for (idx, &v) in on.iter().enumerate() {
        if v {
            let (br, bc) = (idx / nbc, idx % nbc);
            for i in br * bs..((br + 1) * bs).min(rows) {
                for j in bc * bs..((bc + 1) * bs).min(cols) {
                    mask.set(i, j, true);
                }
            }
        }
    }
    mask
}

/// Random unstructured mask at a target sparsity.
pub fn random_mask(rows: usize, cols: usize, sparsity: f64, rng: &mut Rng) -> Mask {
    let nnz = (((1.0 - sparsity) * (rows * cols) as f64).round() as usize)
        .clamp(1, rows * cols);
    Mask::random(rows, cols, nnz, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn nm_rowwise_counts() {
        let mut rng = Rng::new(1);
        let m = nm_mask(8, 32, 2, 8, None, &mut rng);
        for i in 0..8 {
            for g in 0..4 {
                let cnt = (g * 8..(g + 1) * 8).filter(|&j| m.get(i, j)).count();
                assert_eq!(cnt, 2);
            }
        }
        assert!((m.sparsity() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn nm_respects_scores() {
        let mut rng = Rng::new(2);
        let mut scores = vec![0.0f32; 4 * 8];
        scores[0] = 9.0; // row 0, col 0
        scores[5] = 8.0; // row 0, col 5
        let m = nm_mask(4, 8, 2, 8, Some(&scores), &mut rng);
        assert!(m.get(0, 0) && m.get(0, 5));
    }

    #[test]
    fn block_mask_density() {
        forall(
            3,
            30,
            |r| {
                let bs = [2usize, 4, 8][r.below(3)];
                let rows = bs * (1 + r.below(8));
                let cols = bs * (1 + r.below(8));
                let s = 0.3 + 0.6 * r.f64();
                (rows, cols, bs, s, r.fork(1))
            },
            |(rows, cols, bs, s, rng)| {
                let mut rng = rng.clone();
                let active = blocks_for_sparsity(*rows, *cols, *bs, *s);
                let m = block_mask(*rows, *cols, *bs, active, None, &mut rng);
                m.nnz() == active * bs * bs
            },
        );
    }

    #[test]
    fn butterfly_budget_and_diagonal() {
        let m = butterfly_mask(64, 64, 8, 0.8);
        let frac = 1.0 - m.sparsity();
        assert!((0.1..=0.3).contains(&frac), "density {}", frac);
        // block diagonal is always included first
        for i in 0..8 {
            assert!(m.get(i, i));
        }
    }

    #[test]
    fn random_mask_sparsity() {
        let mut rng = Rng::new(4);
        let m = random_mask(32, 32, 0.9, &mut rng);
        assert!((m.sparsity() - 0.9).abs() < 0.01);
    }
}
