//! Host-side soft/hard TopK (Eq. 5) — mirrors `kernels/topk.py`.
//!
//! The in-graph soft TopK trains α; the coordinator uses these host mirrors
//! to (a) monitor the effective nnz trajectory during training (Fig 8),
//! (b) finalize the hard diagonal selection after training, and (c) verify
//! against the golden vectors emitted by the Python oracle.

/// `min(k * softmax(alpha / T), 1)` in f64 for stable accumulation.
pub fn soft_topk(alpha: &[f32], k: f64, temperature: f64) -> Vec<f64> {
    let t = temperature.max(1e-6);
    let mx = alpha.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> =
        alpha.iter().map(|&a| ((a as f64 / t) - mx / t).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.iter().map(|e| (k * e / sum).min(1.0)).collect()
}

/// Indices of the k largest entries (ties broken by lower index).
pub fn hard_topk(alpha: &[f32], k: usize) -> Vec<usize> {
    crate::util::top_k_indices(alpha, k.min(alpha.len()))
}

/// Effective number of "active" diagonals at a threshold — the Fig 8
/// nnz-trajectory statistic (paper counts entries with ᾱ above ~0.5).
pub fn effective_k(alpha: &[f32], k: f64, temperature: f64, thresh: f64) -> usize {
    soft_topk(alpha, k, temperature)
        .into_iter()
        .filter(|&v| v > thresh)
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn bounded_and_ordered() {
        forall(
            20,
            50,
            |r| {
                let d = 4 + r.below(60);
                let k = 1 + r.below(d);
                let t = 0.05 + r.f64() * 5.0;
                let mut rr = r.fork(1);
                let alpha: Vec<f32> =
                    (0..d).map(|_| rr.normal_f32(0.0, 2.0)).collect();
                (alpha, k as f64, t)
            },
            |(alpha, k, t)| {
                let out = soft_topk(alpha, *k, *t);
                // bounded in [0,1], and order-preserving w.r.t. alpha
                out.iter().all(|&v| (0.0..=1.0 + 1e-9).contains(&v))
                    && alpha.iter().zip(&out).all(|(_, _)| true)
                    && {
                        let mut pairs: Vec<(f32, f64)> = alpha
                            .iter()
                            .cloned()
                            .zip(out.iter().cloned())
                            .collect();
                        pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                        pairs.windows(2).all(|w| w[0].1 >= w[1].1 - 1e-12)
                    }
            },
        );
    }

    #[test]
    fn cold_temperature_concentrates() {
        let alpha = [5.0f32, 4.0, 3.0, 0.0, -1.0];
        let out = soft_topk(&alpha, 2.0, 0.01);
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!(out[3] < 1e-9 && out[4] < 1e-9);
    }

    #[test]
    fn hot_temperature_spreads() {
        let alpha = [5.0f32, 4.0, 3.0, 0.0, -1.0];
        let out = soft_topk(&alpha, 2.0, 1e5);
        for &v in &out {
            assert!((v - 2.0 / 5.0).abs() < 1e-3, "{:?}", out);
        }
    }

    #[test]
    fn hard_topk_picks_largest() {
        let alpha = [0.5f32, 3.0, -1.0, 2.0, 2.5];
        let mut got = hard_topk(&alpha, 3);
        got.sort_unstable();
        assert_eq!(got, vec![1, 3, 4]);
    }

    #[test]
    fn effective_k_tracks_temperature() {
        let mut rng = Rng::new(21);
        let alpha: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let hot = effective_k(&alpha, 8.0, 10.0, 0.1);
        let cold = effective_k(&alpha, 8.0, 0.05, 0.1);
        assert!(hot >= cold, "hot {} cold {}", hot, cold);
        assert!(cold <= 9);
    }
}
