//! Per-layer sparsity budget allocation (Table 14 ablation).
//!
//! Given a global sparsity budget and the sparse layers' shapes, produce a
//! per-layer sparsity vector under one of three schemes:
//!   * Uniform          — every layer at the global rate
//!   * ERK              — Erdős–Rényi-Kernel scaling (Evci et al. 2020)
//!   * ComputeFraction  — density proportional to a layer's share of
//!                        compute (Pixelated Butterfly), the paper's default

/// Allocation scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    Uniform,
    Erk,
    ComputeFraction,
}

impl Distribution {
    pub fn parse(s: &str) -> Option<Distribution> {
        match s {
            "uniform" => Some(Distribution::Uniform),
            "erk" => Some(Distribution::Erk),
            "compute" | "compute_fraction" | "pbfly" => {
                Some(Distribution::ComputeFraction)
            }
            _ => None,
        }
    }

    /// Canonical name, round-trippable through [`Distribution::parse`]
    /// (checkpoint serialization relies on this).
    pub fn name(self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Erk => "erk",
            Distribution::ComputeFraction => "compute_fraction",
        }
    }
}

/// Shape of one sparse layer (rows = n_out, cols = n_in).
#[derive(Clone, Copy, Debug)]
pub struct LayerShape {
    pub n_out: usize,
    pub n_in: usize,
}

impl LayerShape {
    pub fn params(&self) -> f64 {
        (self.n_out * self.n_in) as f64
    }
}

/// Compute per-layer sparsities such that total nnz ≈ (1-S_global)·Σ params.
///
/// Returned sparsities are clamped to [0, max_sparsity] — a layer can be
/// denser than the budget (small layers under ERK) but never empty
/// (layer-collapse guard, Sec 4.3.2).
pub fn allocate(
    dist: Distribution,
    layers: &[LayerShape],
    global_sparsity: f64,
    max_sparsity: f64,
) -> Vec<f64> {
    assert!(!layers.is_empty());
    let total: f64 = layers.iter().map(|l| l.params()).sum();
    let budget_nnz = (1.0 - global_sparsity) * total;

    // raw per-layer density scores
    let scores: Vec<f64> = match dist {
        Distribution::Uniform => vec![1.0; layers.len()],
        Distribution::Erk => layers
            .iter()
            .map(|l| (l.n_out + l.n_in) as f64 / (l.n_out * l.n_in) as f64)
            .collect(),
        Distribution::ComputeFraction => {
            // density ∝ layer's fraction of total FLOPs ≈ params share;
            // bigger layers get relatively denser budgets in absolute terms
            // but equal *relative* density; PBFly then boosts small layers.
            layers
                .iter()
                .map(|l| 1.0 / (l.params() / total).sqrt())
                .collect()
        }
    };

    // scale scores so sum(score_l * eps * params_l) == budget
    let denom: f64 = layers
        .iter()
        .zip(&scores)
        .map(|(l, s)| s * l.params())
        .sum();
    let eps = budget_nnz / denom;

    let mut sp: Vec<f64> = scores
        .iter()
        .map(|s| (1.0 - s * eps).clamp(0.0, max_sparsity))
        .collect();

    // clamping may free / consume budget; one correction pass redistributes
    // over the unclamped layers
    for _ in 0..4 {
        let nnz_now: f64 = layers
            .iter()
            .zip(&sp)
            .map(|(l, s)| (1.0 - s) * l.params())
            .sum();
        let err = nnz_now - budget_nnz;
        if err.abs() / budget_nnz < 1e-3 {
            break;
        }
        let free: f64 = layers
            .iter()
            .zip(&sp)
            .filter(|(_, &s)| s > 0.0 && s < max_sparsity)
            .map(|(l, _)| l.params())
            .sum();
        if free <= 0.0 {
            break;
        }
        let delta = err / free;
        for (l, s) in layers.iter().zip(sp.iter_mut()) {
            if *s > 0.0 && *s < max_sparsity {
                *s = (*s + delta * l.params() / l.params()).clamp(0.0, max_sparsity);
            }
        }
    }
    sp
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vit_like() -> Vec<LayerShape> {
        let mut v = Vec::new();
        for _ in 0..4 {
            v.push(LayerShape { n_out: 128, n_in: 128 });
            v.push(LayerShape { n_out: 256, n_in: 128 });
            v.push(LayerShape { n_out: 128, n_in: 256 });
        }
        v
    }

    fn total_sparsity(layers: &[LayerShape], sp: &[f64]) -> f64 {
        let total: f64 = layers.iter().map(|l| l.params()).sum();
        let nnz: f64 = layers
            .iter()
            .zip(sp)
            .map(|(l, s)| (1.0 - s) * l.params())
            .sum();
        1.0 - nnz / total
    }

    #[test]
    fn uniform_hits_global_budget_exactly() {
        let layers = vit_like();
        for &s in &[0.5, 0.8, 0.9, 0.95] {
            let sp = allocate(Distribution::Uniform, &layers, s, 0.999);
            for &x in &sp {
                assert!((x - s).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn erk_and_compute_respect_budget() {
        let layers = vit_like();
        for dist in [Distribution::Erk, Distribution::ComputeFraction] {
            for &s in &[0.6, 0.9] {
                let sp = allocate(dist, &layers, s, 0.999);
                let got = total_sparsity(&layers, &sp);
                assert!(
                    (got - s).abs() < 0.02,
                    "{:?} S={} got {}",
                    dist,
                    s,
                    got
                );
                assert!(sp.iter().all(|&x| (0.0..=0.999).contains(&x)));
            }
        }
    }

    #[test]
    fn erk_gives_small_layers_more_density() {
        let layers = vec![
            LayerShape { n_out: 64, n_in: 64 },
            LayerShape { n_out: 512, n_in: 512 },
        ];
        let sp = allocate(Distribution::Erk, &layers, 0.9, 0.999);
        assert!(sp[0] < sp[1], "small layer should be denser: {:?}", sp);
    }

    #[test]
    fn never_fully_prunes_a_layer() {
        let layers = vit_like();
        let sp = allocate(Distribution::Erk, &layers, 0.99, 0.995);
        assert!(sp.iter().all(|&x| x <= 0.995));
    }
}
