//! Sparsity substrate: masks, diagonal algebra, TopK, schedules, budgets,
//! structured pattern generators.  (DESIGN.md §3.)

pub mod diagonal;
pub mod distribution;
pub mod mask;
pub mod patterns;
pub mod schedule;
pub mod topk;

pub use diagonal::{diag_count, DiagMatrix};
pub use distribution::{allocate, Distribution, LayerShape};
pub use mask::Mask;
pub use schedule::Curve;
