//! Binary masks over 2-D weight matrices.
//!
//! Every DST baseline mutates a `Mask` between train steps; the trainer
//! uploads it as the `masks/<layer>` input of the masked artifacts (as f32
//! 0/1 buffers). DynaDiag itself never materializes a mask during training —
//! its structure lives in α — but produces one at finalization for the
//! small-world analysis (Table 16) and the BCSR conversion.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Dense boolean mask with row-major layout, shape [rows, cols].
///
/// ```
/// use dynadiag::sparsity::mask::Mask;
///
/// let mut m = Mask::zeros(2, 3);
/// m.set(0, 1, true);
/// m.set(1, 2, true);
/// assert_eq!(m.nnz(), 2);
/// assert!((m.sparsity() - 4.0 / 6.0).abs() < 1e-12);
/// // the f32 upload buffer is the 0/1 image of the bits
/// assert_eq!(m.to_f32(), vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub bits: Vec<bool>,
}

impl Mask {
    pub fn zeros(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, bits: vec![false; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, bits: vec![true; rows * cols] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.bits[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.bits[i * self.cols + j] = v;
    }

    pub fn nnz(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.density()
    }

    /// Random unstructured mask with exactly `nnz` active weights.
    pub fn random(rows: usize, cols: usize, nnz: usize, rng: &mut Rng) -> Mask {
        let mut m = Mask::zeros(rows, cols);
        for idx in rng.choose_k(rows * cols, nnz.min(rows * cols)) {
            m.bits[idx] = true;
        }
        m
    }

    /// f32 0/1 buffer for upload as an artifact input.
    pub fn to_f32(&self) -> Vec<f32> {
        self.bits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect()
    }

    /// Write the 0/1 f32 buffer into a caller-provided buffer (the
    /// allocation-free twin of [`Mask::to_f32`], used by the trainer's
    /// workspace-pooled upload path). `out.len()` must be `rows * cols`.
    pub fn to_f32_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.bits.len(), "to_f32_into: length mismatch");
        for (o, &b) in out.iter_mut().zip(&self.bits) {
            *o = if b { 1.0 } else { 0.0 };
        }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor { shape: vec![self.rows, self.cols], data: self.to_f32() }
    }

    pub fn from_tensor(t: &Tensor, thresh: f32) -> Mask {
        assert_eq!(t.rank(), 2);
        Mask {
            rows: t.rows(),
            cols: t.cols(),
            bits: t.data.iter().map(|&x| x.abs() > thresh).collect(),
        }
    }

    /// Transpose (used by the Apdx A invariance tests).
    pub fn transpose(&self) -> Mask {
        let mut out = Mask::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                if self.get(i, j) {
                    out.set(j, i, true);
                }
            }
        }
        out
    }

    /// True if every row and every column has at least one active entry —
    /// the Apdx B full-coverage condition.
    pub fn full_coverage(&self) -> bool {
        let mut row_ok = vec![false; self.rows];
        let mut col_ok = vec![false; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                if self.get(i, j) {
                    row_ok[i] = true;
                    col_ok[j] = true;
                }
            }
        }
        row_ok.into_iter().all(|x| x) && col_ok.into_iter().all(|x| x)
    }

    /// Indices of active entries (row-major order).
    pub fn active_indices(&self) -> Vec<(usize, usize)> {
        let mut v = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            for j in 0..self.cols {
                if self.get(i, j) {
                    v.push((i, j));
                }
            }
        }
        v
    }

    /// Per-row nnz counts.
    pub fn row_nnz(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|i| (0..self.cols).filter(|&j| self.get(i, j)).count())
            .collect()
    }

    /// Per-column nnz counts.
    pub fn col_nnz(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                if self.get(i, j) {
                    counts[j] += 1;
                }
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn random_mask_has_exact_nnz() {
        let mut rng = Rng::new(1);
        let m = Mask::random(10, 20, 37, &mut rng);
        assert_eq!(m.nnz(), 37);
        assert!((m.sparsity() - (1.0 - 37.0 / 200.0)).abs() < 1e-12);
    }

    #[test]
    fn transpose_preserves_nnz() {
        forall(
            2,
            50,
            |r| {
                let rows = 1 + r.below(16);
                let cols = 1 + r.below(16);
                let nnz = r.below(rows * cols + 1);
                let mut rr = r.fork(9);
                Mask::random(rows, cols, nnz, &mut rr)
            },
            |m| {
                let t = m.transpose();
                t.nnz() == m.nnz() && t.transpose() == *m
            },
        );
    }

    #[test]
    fn coverage_detects_empty_rows() {
        let mut m = Mask::ones(3, 3);
        assert!(m.full_coverage());
        for j in 0..3 {
            m.set(1, j, false);
        }
        assert!(!m.full_coverage());
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(3);
        let m = Mask::random(6, 7, 20, &mut rng);
        let t = m.to_tensor();
        assert_eq!(Mask::from_tensor(&t, 0.5), m);
    }

    #[test]
    fn row_col_counts_sum_to_nnz() {
        let mut rng = Rng::new(4);
        let m = Mask::random(9, 11, 40, &mut rng);
        assert_eq!(m.row_nnz().iter().sum::<usize>(), 40);
        assert_eq!(m.col_nnz().iter().sum::<usize>(), 40);
    }
}
