//! Statistical machinery for the paper's significance claims (Tables 9–11):
//! paired asymptotic McNemar tests with χ²(1) p-values, plus summary helpers.

/// Outcome counts of a paired comparison on the same test instances.
#[derive(Clone, Copy, Debug, Default)]
pub struct PairedCounts {
    /// both correct
    pub both: usize,
    /// only method A correct
    pub a_only: usize,
    /// only method B correct
    pub b_only: usize,
    /// both wrong
    pub neither: usize,
}

impl PairedCounts {
    /// Tally from per-instance correctness vectors.
    pub fn from_correct(a: &[bool], b: &[bool]) -> PairedCounts {
        assert_eq!(a.len(), b.len(), "paired test needs equal-length vectors");
        let mut c = PairedCounts::default();
        for (&x, &y) in a.iter().zip(b) {
            match (x, y) {
                (true, true) => c.both += 1,
                (true, false) => c.a_only += 1,
                (false, true) => c.b_only += 1,
                (false, false) => c.neither += 1,
            }
        }
        c
    }

    pub fn n(&self) -> usize {
        self.both + self.a_only + self.b_only + self.neither
    }
}

/// Asymptotic McNemar test with continuity correction:
/// χ² = (|b−c|−1)² / (b+c), df=1. Returns (chi2, p).
///
/// Only the discordant pairs (a_only, b_only) matter; if there are none the
/// methods are indistinguishable (p = 1).
pub fn mcnemar(counts: &PairedCounts) -> (f64, f64) {
    let b = counts.a_only as f64;
    let c = counts.b_only as f64;
    if b + c == 0.0 {
        return (0.0, 1.0);
    }
    let num = ((b - c).abs() - 1.0).max(0.0);
    let chi2 = num * num / (b + c);
    (chi2, chi2_sf_df1(chi2))
}

/// Survival function of χ²(1): P(X > x) = erfc(sqrt(x/2)).
pub fn chi2_sf_df1(x: f64) -> f64 {
    if x <= 0.0 {
        return 1.0;
    }
    erfc((x / 2.0).sqrt())
}

/// Complementary error function (Numerical Recipes rational approximation,
/// |error| < 1.2e-7 everywhere).
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223
                                            + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Per-example accuracy vector -> accuracy.
pub fn accuracy(correct: &[bool]) -> f64 {
    if correct.is_empty() {
        return 0.0;
    }
    correct.iter().filter(|&&c| c).count() as f64 / correct.len() as f64
}

/// Significance table row: compare each method against a reference.
#[derive(Clone, Debug)]
pub struct McNemarRow {
    pub method: String,
    pub chi2: f64,
    pub p: f64,
    /// true if not significantly different at alpha = 0.05
    pub not_different: bool,
}

pub fn mcnemar_vs_reference(
    reference: &[bool],
    others: &[(String, Vec<bool>)],
    alpha: f64,
) -> Vec<McNemarRow> {
    others
        .iter()
        .map(|(name, correct)| {
            let counts = PairedCounts::from_correct(reference, correct);
            let (chi2, p) = mcnemar(&counts);
            McNemarRow {
                method: name.clone(),
                chi2,
                p,
                not_different: p >= alpha,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn erfc_reference_values() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!((erfc(1.0) - 0.157299).abs() < 1e-5);
        assert!((erfc(-1.0) - 1.842701).abs() < 1e-5);
        assert!(erfc(4.0) < 1e-7);
    }

    #[test]
    fn chi2_sf_known_quantiles() {
        // chi2(1) critical value at p=0.05 is 3.841
        assert!((chi2_sf_df1(3.841) - 0.05).abs() < 2e-3);
        assert!((chi2_sf_df1(6.635) - 0.01).abs() < 1e-3);
    }

    #[test]
    fn identical_methods_not_significant() {
        let a = vec![true, false, true, true, false];
        let counts = PairedCounts::from_correct(&a, &a);
        let (_, p) = mcnemar(&counts);
        assert_eq!(p, 1.0);
    }

    #[test]
    fn lopsided_discordance_is_significant() {
        // A correct on 40 instances B misses, B correct on 5 A misses
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..40 {
            a.push(true);
            b.push(false);
        }
        for _ in 0..5 {
            a.push(false);
            b.push(true);
        }
        for _ in 0..100 {
            a.push(true);
            b.push(true);
        }
        let (chi2, p) = mcnemar(&PairedCounts::from_correct(&a, &b));
        assert!(chi2 > 20.0);
        assert!(p < 1e-5);
    }

    #[test]
    fn symmetric_noise_not_significant() {
        let mut rng = Rng::new(1);
        let n = 2000;
        let a: Vec<bool> = (0..n).map(|_| rng.bool(0.8)).collect();
        let b: Vec<bool> = (0..n).map(|_| rng.bool(0.8)).collect();
        let (_, p) = mcnemar(&PairedCounts::from_correct(&a, &b));
        assert!(p > 0.01, "independent same-rate methods flagged: p={}", p);
    }

    #[test]
    fn counts_partition() {
        let a = vec![true, true, false, false];
        let b = vec![true, false, true, false];
        let c = PairedCounts::from_correct(&a, &b);
        assert_eq!((c.both, c.a_only, c.b_only, c.neither), (1, 1, 1, 1));
        assert_eq!(c.n(), 4);
    }
}
