//! Substrate utilities: PRNG, JSON, timing, logging, property testing.
//!
//! Everything here is hand-rolled because the offline crate registry only
//! carries the `xla` closure (DESIGN.md §2 substitution table).

pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=error 2=info 3=debug

/// Set global log verbosity (0..=3).
pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level.min(3), Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

/// info-level log line (stderr; stdout is reserved for results).
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

/// debug-level log line.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(3) {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

/// Write a file atomically: bytes land in a uniquely named
/// `<file>.tmp.<pid>.<seq>` sibling first and are `rename`d into place, so
/// a concurrent reader sees either the old complete file or the new
/// complete file, never a partial write — even with concurrent publishers
/// to the same path. The temp file is removed on either failure path.
/// Shared by the artifact writers ([`crate::artifact`]) and
/// [`json::Json::write_file`].
pub fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> anyhow::Result<()> {
    use anyhow::Context as _;
    use std::sync::atomic::{AtomicU64, Ordering};
    let file_name = path.file_name().ok_or_else(|| {
        anyhow::anyhow!("path '{}' has no file name", path.display())
    })?;
    // unique tmp name per (process, call): two concurrent publishers to
    // the same path must never share a tmp file, or one could rename the
    // other's half-written bytes into place
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let tmp = path.with_file_name(format!(
        "{}.tmp.{}.{}",
        file_name.to_string_lossy(),
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    if let Err(e) = std::fs::write(&tmp, bytes) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("writing {}", tmp.display()));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("renaming {} into place", path.display()));
    }
    Ok(())
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Indices that would sort `xs` descending (stable).
pub fn argsort_desc(xs: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

/// Top-k indices by value (descending).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx = argsort_desc(xs);
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_helpers() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138)
            .abs()
            < 0.01);
    }

    #[test]
    fn argsort_and_topk() {
        let xs = [0.1f32, 5.0, -2.0, 3.0];
        assert_eq!(argsort_desc(&xs), vec![1, 3, 0, 2]);
        assert_eq!(top_k_indices(&xs, 2), vec![1, 3]);
    }
}
