//! Substrate utilities: PRNG, JSON, timing, logging, property testing.
//!
//! Everything here is hand-rolled because the offline crate registry only
//! carries the `xla` closure (DESIGN.md §2 substitution table).

pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;

use std::sync::atomic::{AtomicU8, Ordering};

static LOG_LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=error 2=info 3=debug

/// Set global log verbosity (0..=3).
pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level.min(3), Ordering::Relaxed);
}

pub fn log_enabled(level: u8) -> bool {
    LOG_LEVEL.load(Ordering::Relaxed) >= level
}

/// info-level log line (stderr; stdout is reserved for results).
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(2) {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

/// debug-level log line.
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_enabled(3) {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Indices that would sort `xs` descending (stable).
pub fn argsort_desc(xs: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

/// Top-k indices by value (descending).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx = argsort_desc(xs);
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_helpers() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138)
            .abs()
            < 0.01);
    }

    #[test]
    fn argsort_and_topk() {
        let xs = [0.1f32, 5.0, -2.0, 3.0];
        assert_eq!(argsort_desc(&xs), vec![1, 3, 0, 2]);
        assert_eq!(top_k_indices(&xs, 2), vec![1, 3]);
    }
}
