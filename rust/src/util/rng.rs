//! Deterministic PRNG for the coordinator (no `rand` crate offline).
//!
//! PCG-XSH-RR 64/32 with a SplitMix64-seeded stream. Every stochastic piece
//! of the system (mask init, prune/grow draws, data generation, property
//! tests) draws from an explicitly seeded `Rng`, so experiment cells are
//! exactly reproducible from their config seed.

/// PCG32 generator (64-bit state, 32-bit output), O'Neill 2014.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal deviate from Box-Muller
    spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give distinct streams.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let init_state = splitmix64(&mut s);
        let init_inc = splitmix64(&mut s) | 1;
        let mut rng = Rng { state: 0, inc: init_inc, spare: None };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (for per-layer / per-cell rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the full generator state for checkpointing:
    /// (PCG state, stream increment, cached Box-Muller spare).
    pub fn state(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.spare)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot. The restored
    /// stream continues bit-identically from where the snapshot was taken
    /// (train-resume relies on this).
    pub fn from_state(state: u64, inc: u64, spare: Option<f64>) -> Rng {
        Rng { state, inc, spare }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.f64();
            if u <= f64::EPSILON {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// k distinct draws from 0..n (k <= n), in random order.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k {} > n {}", k, n);
        if k * 3 > n {
            let mut p = self.permutation(n);
            p.truncate(k);
            return p;
        }
        // sparse rejection sampling for small k
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_in_bounds() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {}", mean);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{:?}", counts);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::new(4);
        for &(n, k) in &[(10, 10), (100, 3), (50, 25), (1, 1)] {
            let v = r.choose_k(n, k);
            assert_eq!(v.len(), k);
            let set: std::collections::HashSet<_> = v.iter().collect();
            assert_eq!(set.len(), k);
            assert!(v.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn state_snapshot_resumes_bit_identically() {
        let mut r = Rng::new(12);
        // draw an odd number of normals so a Box-Muller spare is cached
        for _ in 0..7 {
            r.normal();
        }
        let (s, i, spare) = r.state();
        assert!(spare.is_some(), "odd normal count must leave a spare");
        let mut resumed = Rng::from_state(s, i, spare);
        for _ in 0..100 {
            assert_eq!(r.normal(), resumed.normal());
            assert_eq!(r.next_u64(), resumed.next_u64());
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
