//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` inputs from `gen` and
//! asserts `prop` on each; on failure it reports the failing case index and
//! a debug dump of the input, plus the seed to replay. Used throughout the
//! crate for the paper's invariants (Apdx A transposition, Apdx B coverage,
//! DST budget conservation, BCSR round-trips, coordinator state machines).

use super::rng::Rng;

/// Run `prop` on `cases` values drawn by `gen`. Panics with a replayable
/// report on the first failure.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {}/{} (seed {}):\n{:#?}",
                case, cases, seed, input
            );
        }
    }
}

/// Like `forall` but the property returns `Result<(), String>` so failures
/// can carry a message about *which* invariant broke.
pub fn forall_explain<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {}/{} (seed {}): {}\ninput: {:#?}",
                case, cases, seed, msg, input
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(1, 50, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        forall(2, 50, |r| r.below(10), |&x| x < 5);
    }

    #[test]
    fn explain_variant() {
        forall_explain(
            3,
            20,
            |r| (r.below(8), r.below(8)),
            |&(a, b)| {
                if a + b < 16 {
                    Ok(())
                } else {
                    Err(format!("sum {} too big", a + b))
                }
            },
        );
    }
}
