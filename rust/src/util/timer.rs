//! Wall-clock timing helpers for the bench harness (criterion is
//! unavailable offline; `benches/` use these instead).

use std::time::Instant;

/// Stopwatch measuring elapsed seconds.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        // ddlint: allow(clock) -- bench stopwatch; never on a serving path
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Result of a repeated measurement.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
}

/// Measure `f` with warmup, returning per-iteration stats.
///
/// Runs `warmup` untimed calls, then times `iters` calls individually —
/// individual timing (not amortized) so min/σ expose scheduling noise,
/// which matters on the single shared CPU core the CI runs on.
pub fn bench<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now(); // ddlint: allow(clock) -- bench iteration timing
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / samples.len() as f64;
    BenchStats { iters, mean_s: mean, min_s: min, max_s: max, std_s: var.sqrt() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0usize;
        let stats = bench(2, 5, || {
            n += 1;
            n
        });
        assert_eq!(n, 7);
        assert_eq!(stats.iters, 5);
        assert!(stats.min_s <= stats.mean_s && stats.mean_s <= stats.max_s);
    }
}
