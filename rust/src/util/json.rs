//! Minimal JSON parse/serialize (serde is unavailable offline).
//!
//! Covers exactly what the repo needs: the artifact manifest, golden test
//! vectors, and experiment result files. Numbers parse as f64; helper
//! accessors do the usize/i64 casts the call sites want.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{}'", key))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {:?}", self.kind()),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {:?}", self.kind()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {:?}", self.kind()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {:?}", self.kind()),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {:?}", self.kind()),
        }
    }

    /// Array of numbers -> Vec<f32> (golden vectors).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn as_i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as i32)).collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // -- construction helpers ------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Human-readable serialization: 2-space indentation, one key/element
    /// per line. Same value model as [`Json::to_string`] (re-parses equal);
    /// used for artifact metadata sidecars and anything ops will read.
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    /// Write pretty-printed JSON to a file atomically (temp file + rename),
    /// so readers never observe a partial document.
    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        crate::util::write_atomic(path, self.to_pretty_string().as_bytes())
    }

    fn write_num(out: &mut String, n: f64) {
        // JSON has no NaN/Infinity literals; emit null rather than an
        // unparseable token (a bench cell with 0 observations stays valid)
        if !n.is_finite() {
            out.push_str("null");
        } else if n.fract() == 0.0 && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{}", n);
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        const INDENT: &str = "  ";
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    for _ in 0..=depth {
                        out.push_str(INDENT);
                    }
                    x.write_pretty(out, depth + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..depth {
                    out.push_str(INDENT);
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    for _ in 0..=depth {
                        out.push_str(INDENT);
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write_pretty(out, depth + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..depth {
                    out.push_str(INDENT);
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => Json::write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c => {
                    // re-sync to char boundary for multi-byte utf8
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let chunk =
                            std::str::from_utf8(&self.b[start..start + width])?;
                        s.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("bad number '{}' at byte {}", text, start)
        })?))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("b").unwrap().req("c").unwrap().as_str().unwrap(),
            "x\ny"
        );
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""café δ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café δ");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_i64().unwrap(), 42);
        assert!((Json::parse("-1.5e-3").unwrap().as_f64().unwrap()
            + 0.0015)
            .abs()
            < 1e-12);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn pretty_print_reparses_equal() {
        let src = r#"{"a": [1, 2.5], "b": {"c": "x\ny"}, "d": [], "e": {}}"#;
        let v = Json::parse(src).unwrap();
        let pretty = v.to_pretty_string();
        assert!(pretty.contains("\n  \"a\": ["), "{}", pretty);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn non_finite_numbers_emit_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(1.0).to_string(), "1");
    }

    #[test]
    fn write_file_is_readable_and_atomic() {
        let dir = std::env::temp_dir().join("dynadiag_json_write_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.json");
        let v = Json::obj(vec![("k", Json::Num(2.0))]);
        v.write_file(&path).unwrap();
        assert_eq!(Json::from_file(&path).unwrap(), v);
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name().to_string_lossy().into_owned();
            assert!(!name.contains(".tmp"), "leftover temp file {}", name);
        }
    }

    #[test]
    fn vec_accessors() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.as_i32_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }
}
