//! End-to-end model-level timing composition (Fig 1 / Fig 4 / Fig 9 / Tbl 8).
//!
//! Composes per-layer kernel times into per-step inference and training
//! times for the paper's evaluation network (ViT-B/16 on 224² images,
//! 197 tokens) under each DST method's execution strategy, including the
//! paper's infrastructure caveats (SRigL & DSB train dense — footnote 4).

use super::{linear_bwd, linear_fwd, Device, ExecFormat, A100};

/// Transformer shape for the timing model.
#[derive(Clone, Copy, Debug)]
pub struct NetShape {
    pub tokens: usize,
    pub dim: usize,
    pub mlp: usize,
    pub depth: usize,
    pub batch: usize,
    /// sparsify MHA input projections too (GPT-2 yes, ViT no — footnotes 2/3)
    pub sparse_qkv: bool,
}

/// ViT-Base/16, ImageNet: 197 tokens (196 + cls), 768 dim, 12 blocks.
pub const VIT_BASE: NetShape = NetShape {
    tokens: 197,
    dim: 768,
    mlp: 3072,
    depth: 12,
    batch: 128,
    sparse_qkv: false,
};

/// GPT-2 Small shape on WikiText-103 (1024 ctx).
pub const GPT2_SMALL: NetShape = NetShape {
    tokens: 1024,
    dim: 768,
    mlp: 3072,
    depth: 12,
    batch: 8,
    sparse_qkv: true,
};

/// A DST method's execution profile (Sec 4.2.3 "Setup").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Dense,
    RigL,
    Set,
    Mest,
    Cht,
    SRigL,
    Dsb,
    PixelatedBFly,
    DiagHeur,
    DynaDiag,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Dense => "Dense",
            Method::RigL => "RigL",
            Method::Set => "SET",
            Method::Mest => "MEST",
            Method::Cht => "CHT",
            Method::SRigL => "SRigL",
            Method::Dsb => "DSB",
            Method::PixelatedBFly => "PixelatedBFly",
            Method::DiagHeur => "DiagHeur",
            Method::DynaDiag => "DynaDiag",
        }
    }

    pub fn structured(&self) -> bool {
        matches!(
            self,
            Method::SRigL
                | Method::Dsb
                | Method::PixelatedBFly
                | Method::DiagHeur
                | Method::DynaDiag
        )
    }

    /// Inference-time layer format.
    pub fn infer_format(&self) -> ExecFormat {
        match self {
            Method::Dense => ExecFormat::Dense,
            Method::RigL | Method::Set | Method::Mest | Method::Cht => ExecFormat::Csr,
            Method::SRigL => ExecFormat::Nm24,
            Method::Dsb | Method::PixelatedBFly => ExecFormat::TritonBlock,
            Method::DiagHeur | Method::DynaDiag => ExecFormat::DiagBcsr,
        }
    }

    /// Training-time layer format (footnote 4: SRigL's and DSB's kernels
    /// lack training integration — they train dense; PBFly's Triton lib
    /// does train sparse).
    pub fn train_format(&self) -> ExecFormat {
        match self {
            Method::Dense | Method::SRigL | Method::Dsb => ExecFormat::Dense,
            Method::RigL | Method::Set | Method::Mest | Method::Cht => ExecFormat::Csr,
            Method::PixelatedBFly => ExecFormat::TritonBlock,
            Method::DiagHeur | Method::DynaDiag => ExecFormat::DiagBcsr,
        }
    }

    /// Does the method keep the backward pass sparse?
    pub fn sparse_bwd(&self) -> bool {
        matches!(
            self,
            Method::DynaDiag | Method::DiagHeur | Method::PixelatedBFly
        ) || matches!(self, Method::RigL | Method::Set | Method::Mest | Method::Cht)
    }
}

/// Dense (non-sparsifiable) compute per block: attention score/value matmuls
/// + layernorms + softmax, approximated by their GEMM cost.
fn attn_core_time(dev: &Device, s: &NetShape) -> f64 {
    let b = s.batch;
    // q@kT and att@v per head batch: 2 gemms of [tokens, tokens, dim]
    2.0 * dev.gemm(b * s.tokens, s.tokens, s.dim)
}

/// Per-step inference time of the full network under `method`.
pub fn inference_time(method: Method, s: &NetShape, sparsity: f64) -> f64 {
    let dev = &A100;
    let fmt = method.infer_format();
    let b = s.batch * s.tokens; // linear layers see flattened tokens
    let mut t = 0.0;
    for _ in 0..s.depth {
        // qkv projection
        t += if s.sparse_qkv && method != Method::Dense {
            linear_fwd(dev, fmt, b, 3 * s.dim, s.dim, sparsity)
        } else {
            dev.gemm(b, 3 * s.dim, s.dim)
        };
        t += attn_core_time(dev, s);
        // attn out projection + mlp (the sparsified layers)
        if method == Method::Dense {
            t += dev.gemm(b, s.dim, s.dim);
            t += dev.gemm(b, s.mlp, s.dim);
            t += dev.gemm(b, s.dim, s.mlp);
        } else {
            t += linear_fwd(dev, fmt, b, s.dim, s.dim, sparsity);
            t += linear_fwd(dev, fmt, b, s.mlp, s.dim, sparsity);
            t += linear_fwd(dev, fmt, b, s.dim, s.mlp, sparsity);
        }
    }
    // one-off diag→BCSR conversion is amortized across the serving batch
    // stream; charge a vanishing share here (Fig 7 reports it separately).
    t
}

/// Per-step training time (fwd + bwd + optimizer traffic).
pub fn train_step_time(method: Method, s: &NetShape, sparsity: f64) -> f64 {
    let dev = &A100;
    let fmt = method.train_format();
    let sb = method.sparse_bwd() && fmt != ExecFormat::Dense;
    let b = s.batch * s.tokens;
    let mut t = 0.0;
    for _ in 0..s.depth {
        let qkv_sparse = s.sparse_qkv && fmt != ExecFormat::Dense;
        // forward
        t += if qkv_sparse {
            linear_fwd(dev, fmt, b, 3 * s.dim, s.dim, sparsity)
        } else {
            dev.gemm(b, 3 * s.dim, s.dim)
        };
        t += attn_core_time(dev, s);
        let layers = [(s.dim, s.dim), (s.mlp, s.dim), (s.dim, s.mlp)];
        for &(o, i) in &layers {
            t += if fmt == ExecFormat::Dense {
                dev.gemm(b, o, i)
            } else {
                linear_fwd(dev, fmt, b, o, i, sparsity)
            };
        }
        // backward: attention core ~2x fwd, linears via linear_bwd
        t += 2.0 * attn_core_time(dev, s);
        t += if qkv_sparse {
            linear_bwd(dev, fmt, b, 3 * s.dim, s.dim, sparsity, sb)
        } else {
            linear_bwd(dev, ExecFormat::Dense, b, 3 * s.dim, s.dim, 0.0, false)
        };
        for &(o, i) in &layers {
            t += linear_bwd(dev, fmt, b, o, i, sparsity, sb);
        }
    }
    // optimizer update traffic: params touched ∝ density for sparse methods
    let params = s.depth as f64
        * (3.0 * (s.dim * s.dim) as f64
            + (s.dim * s.dim) as f64
            + 2.0 * (s.dim * s.mlp) as f64);
    let touched = if fmt == ExecFormat::Dense { params } else { params * (1.0 - sparsity).max(0.05) };
    t += 3.0 * 4.0 * touched / dev.hbm_bw; // read p/m/v + write, fp32
    // diagonal values change every step, so DynaDiag re-packs diagonals to
    // BCSR each step (Tbl 8's "with BCSR conversion" column measures this
    // overhead); index remap happens only at TopK changes and is ignorable.
    if matches!(method, Method::DynaDiag | Method::DiagHeur) {
        let nnz = (1.0 - sparsity) * params;
        // pack touches values ~3x (read diag layout, write blocks, indices)
        t += 3.0 * dev.diag_convert(nnz as usize);
    }
    // framework overhead every method pays (PyTorch dispatch, augmentation,
    // host sync) — measured training curves flatten toward this floor.
    t += 0.12 * dense_compute_floor(s);
    t
}

/// Cached-ish dense fwd+bwd compute time (the overhead-floor reference).
fn dense_compute_floor(s: &NetShape) -> f64 {
    let dev = &A100;
    let b = s.batch * s.tokens;
    let mut t = 0.0;
    for _ in 0..s.depth {
        t += dev.gemm(b, 3 * s.dim, s.dim);
        t += 3.0 * attn_core_time(dev, s);
        t += 3.0
            * (dev.gemm(b, s.dim, s.dim)
                + dev.gemm(b, s.mlp, s.dim)
                + dev.gemm(b, s.dim, s.mlp));
    }
    t
}

/// Speedup of `method` over dense execution.
pub fn inference_speedup(method: Method, s: &NetShape, sparsity: f64) -> f64 {
    inference_time(Method::Dense, s, 0.0) / inference_time(method, s, sparsity)
}

pub fn train_speedup(method: Method, s: &NetShape, sparsity: f64) -> f64 {
    train_step_time(Method::Dense, s, 0.0) / train_step_time(method, s, sparsity)
}

pub const ALL_METHODS: [Method; 10] = [
    Method::Dense,
    Method::RigL,
    Method::Set,
    Method::Mest,
    Method::Cht,
    Method::SRigL,
    Method::Dsb,
    Method::PixelatedBFly,
    Method::DiagHeur,
    Method::DynaDiag,
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig 1 / Fig 4 headline shape: DynaDiag @90% gives ~3.1× inference
    /// and ~1.59× training speedup on ViT-B; we accept the right ballpark.
    #[test]
    fn vit_base_headline_speedups() {
        let inf = inference_speedup(Method::DynaDiag, &VIT_BASE, 0.9);
        assert!(
            (2.0..=4.5).contains(&inf),
            "DynaDiag 90% inference speedup {} out of band",
            inf
        );
        let tr = train_speedup(Method::DynaDiag, &VIT_BASE, 0.9);
        assert!(
            (1.2..=2.2).contains(&tr),
            "DynaDiag 90% training speedup {} out of band",
            tr
        );
    }

    /// Fig 4: at 60% sparsity inference ~1.37×, training near parity.
    #[test]
    fn vit_base_low_sparsity_tapering() {
        let inf = inference_speedup(Method::DynaDiag, &VIT_BASE, 0.6);
        assert!((1.0..=2.0).contains(&inf), "60% inference {}", inf);
        let tr = train_speedup(Method::DynaDiag, &VIT_BASE, 0.6);
        assert!((0.7..=1.4).contains(&tr), "60% training {}", tr);
    }

    /// The paper's motivation: unstructured (RigL) gets no real speedup.
    #[test]
    fn rigl_has_no_speedup_at_90() {
        let inf = inference_speedup(Method::RigL, &VIT_BASE, 0.9);
        assert!(inf < 1.4, "RigL inference speedup {} too high", inf);
        let tr = train_speedup(Method::RigL, &VIT_BASE, 0.9);
        assert!(tr < 1.3, "RigL train speedup {}", tr);
    }

    /// Fig 1 ordering at 90%: DynaDiag fastest in both axes among methods.
    #[test]
    fn dynadiag_fastest_at_90() {
        let s = 0.9;
        let dd_inf = inference_speedup(Method::DynaDiag, &VIT_BASE, s);
        let dd_tr = train_speedup(Method::DynaDiag, &VIT_BASE, s);
        for m in [Method::RigL, Method::SRigL, Method::Dsb, Method::PixelatedBFly] {
            assert!(
                dd_inf >= inference_speedup(m, &VIT_BASE, s) * 0.99,
                "{:?} beats DynaDiag inference",
                m
            );
            assert!(
                dd_tr >= train_speedup(m, &VIT_BASE, s) * 0.99,
                "{:?} beats DynaDiag training",
                m
            );
        }
    }

    /// footnote 4: SRigL / DSB training is dense -> no training speedup.
    #[test]
    fn srigl_dsb_train_dense() {
        for m in [Method::SRigL, Method::Dsb] {
            let tr = train_speedup(m, &VIT_BASE, 0.9);
            assert!((0.9..=1.02).contains(&tr), "{:?} train speedup {}", m, tr);
        }
    }

    #[test]
    fn srigl_inference_speedup_exists() {
        let sp = inference_speedup(Method::SRigL, &VIT_BASE, 0.9);
        assert!(sp > 1.1, "SRigL inference {}", sp);
    }
}
