//! Analytical A100 kernel-time model (DESIGN.md §2 substitution).
//!
//! The paper measures wall-clock on an NVIDIA A100 with: cuBLAS dense GEMM,
//! cuSPARSE CSR SpMM (RigL), the SmaT tensor-core BCSR kernel (DynaDiag,
//! Apdx D), the PBFly Triton block kernel (PixelatedBFly/DSB), and 2:4
//! sparse tensor cores (SRigL inference).  We model each kernel as
//!
//! ```text
//!     t = max(flops / (peak * eff), bytes / BW) + launch
//! ```
//!
//! with per-kernel-class efficiency factors taken from published
//! measurements (cuSPARSE unstructured SpMM sustains a few percent of tensor
//! core peak; SmaT-style blocked kernels sustain ~40–60% scaled by block
//! density; 2:4 sparse GEMM ≈ 1.6–1.8× dense).  Absolute times are
//! estimates; the *ratios* (Figs 1, 4, 7, Tbl 8) are what we reproduce —
//! they're governed by arithmetic intensity and format overheads, which the
//! model captures.  `benches/fig7_diag_speed.rs` cross-checks the ordering
//! against measured Rust SpMM on the same shapes.

pub mod vit;

/// Device constants (Apdx C lists the A100 80GB).
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// fp16 tensor-core peak, FLOP/s
    pub peak_tc: f64,
    /// fp32 SIMT peak, FLOP/s
    pub peak_fp32: f64,
    /// HBM bandwidth, B/s
    pub hbm_bw: f64,
    /// per-kernel launch + driver overhead, s
    pub launch: f64,
}

pub const A100: Device = Device {
    peak_tc: 312e12,
    peak_fp32: 19.5e12,
    hbm_bw: 2.0e12,
    launch: 4.5e-6,
};

/// Efficiency factors per kernel class (fractions of the relevant peak).
pub mod eff {
    /// cuBLAS fp16 GEMM at transformer sizes
    pub const DENSE: f64 = 0.62;
    /// cuSPARSE CSR SpMM on unstructured patterns, fraction of *tc* peak
    /// (published numbers land at 1–5%; gather-bound)
    pub const CSR: f64 = 0.035;
    /// SmaT-style BCSR tensor-core kernel on fully dense blocks (the SmaT
    /// paper reports ~2× over Triton block kernels at these shapes)
    pub const BCSR: f64 = 0.48;
    /// PBFly Triton block kernel (less tuned than SmaT's PTX mma path)
    pub const TRITON_BLOCK: f64 = 0.22;
    /// 2:4 sparse tensor cores: same pipe efficiency as dense, half flops
    /// (yields the ~1.6–1.8× ceiling NVIDIA reports)
    pub const NM24: f64 = 0.62;
}

impl Device {
    fn roofline(&self, flops: f64, bytes: f64, eff_: f64) -> f64 {
        let t_comp = flops / (self.peak_tc * eff_);
        let t_mem = bytes / self.hbm_bw;
        t_comp.max(t_mem) + self.launch
    }

    /// Dense fp16 GEMM  C[m,n] = A[m,k] · B[k,n].
    pub fn gemm(&self, m: usize, n: usize, k: usize) -> f64 {
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let bytes = 2.0 * (m * k + k * n + m * n) as f64;
        self.roofline(flops, bytes, eff::DENSE)
    }

    /// cuSPARSE CSR SpMM: y[b, rows] = x[b, cols] · Wᵀ, nnz nonzeros.
    pub fn csr_spmm(&self, b: usize, rows: usize, cols: usize, nnz: usize) -> f64 {
        let flops = 2.0 * b as f64 * nnz as f64;
        // vals+col idx (4+4 B), row ptr, x and y panels; gathers defeat
        // coalescing so charge x traffic once per nnz element touched.
        let bytes = 8.0 * nnz as f64
            + 4.0 * rows as f64
            + 2.0 * (b * cols + b * rows) as f64
            + 2.0 * (b.min(8) * nnz) as f64;
        self.roofline(flops, bytes, eff::CSR)
    }

    /// Blocked SpMM over nnzb blocks of bs×bs with given in-block density.
    /// `cols_touched`/`rows_touched` bound the activation panel traffic
    /// (x and y are tiled and reused across block rows, not re-read per
    /// block as a naive count would charge).
    pub fn bcsr_spmm(
        &self,
        b: usize,
        nnzb: usize,
        bs: usize,
        block_density: f64,
        eff_: f64,
        n_out: usize,
        n_in: usize,
    ) -> f64 {
        // tensor cores compute on whole blocks: flops charged on block area
        let flops = 2.0 * b as f64 * (nnzb * bs * bs) as f64;
        let bytes = 2.0 * (nnzb * bs * bs) as f64
            + 8.0 * nnzb as f64
            + 2.0 * (b * n_in + b * n_out) as f64;
        // sparse-in-block waste: effective efficiency scales with density
        let e = eff_ * block_density.clamp(0.05, 1.0).sqrt();
        self.roofline(flops, bytes, e)
    }

    /// 2:4 structured-sparse GEMM (SRigL inference path).
    pub fn nm24_gemm(&self, m: usize, n: usize, k: usize) -> f64 {
        let flops = m as f64 * n as f64 * k as f64; // half the dense flops
        let bytes = 2.0 * (m * k / 2 + k * n + m * n) as f64 + (m * k / 4) as f64;
        self.roofline(flops, bytes, eff::NM24)
    }

    /// One-off diagonal→BCSR conversion: a permuted gather of nnz values
    /// plus index construction — bandwidth bound.  Amortized over the steps
    /// between topology updates during training; paid once for inference.
    pub fn diag_convert(&self, nnz: usize) -> f64 {
        let bytes = 3.0 * 4.0 * nnz as f64;
        bytes / self.hbm_bw + 2.0 * self.launch
    }
}

/// How a sparse linear layer executes, per method (Sec 4.2.3 setup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecFormat {
    Dense,
    /// unstructured CSR (RigL/SET/MEST/CHT)
    Csr,
    /// diagonal → BCSR via SmaT-style kernel (DynaDiag, DiagHeur)
    DiagBcsr,
    /// block-sparse Triton kernel (DSB, PixelatedBFly)
    TritonBlock,
    /// 2:4 tensor cores, inference only (SRigL); training falls back dense
    Nm24,
}

/// Time for `y = x[b, n_in] · Wᵀ` at `sparsity`, in format `fmt`.
pub fn linear_fwd(dev: &Device, fmt: ExecFormat, b: usize, n_out: usize, n_in: usize, sparsity: f64) -> f64 {
    let nnz = (((1.0 - sparsity) * (n_out * n_in) as f64) as usize).max(1);
    match fmt {
        ExecFormat::Dense => dev.gemm(b, n_out, n_in),
        ExecFormat::Csr => dev.csr_spmm(b, n_out, n_in, nnz),
        ExecFormat::DiagBcsr => {
            // K whole diagonals; the Apdx D reorder clusters the selected
            // band into near-dense blocks: ceil(k/bs) full blocks plus one
            // partial edge block per block row.
            let bs = 32;
            let k = crate::sparsity::diag_count(n_in, sparsity);
            let nnzb = (n_out / bs).max(1) * (k.div_ceil(bs) + 1);
            let density = (k as f64 * n_out as f64) / (nnzb * bs * bs) as f64;
            dev.bcsr_spmm(b, nnzb, bs, density.min(1.0), eff::BCSR, n_out, n_in)
        }
        ExecFormat::TritonBlock => {
            let bs = 32;
            let total = ((n_out / bs) * (n_in / bs)).max(1);
            let nnzb = (((1.0 - sparsity) * total as f64) as usize).max(1);
            dev.bcsr_spmm(b, nnzb, bs, 1.0, eff::TRITON_BLOCK, n_out, n_in)
        }
        ExecFormat::Nm24 => dev.nm24_gemm(b, n_out, n_in),
    }
}

/// Backward products for one linear: dX = dY·W and dW = dYᵀ·X.
/// `sparse_bwd`: method keeps the backward sparse (DynaDiag via Apdx A,
/// PBFly/DSB block kernels); otherwise dense fallback (SRigL, and RigL's
/// dW is dense by construction).
pub fn linear_bwd(dev: &Device, fmt: ExecFormat, b: usize, n_out: usize, n_in: usize, sparsity: f64, sparse_bwd: bool) -> f64 {
    if !sparse_bwd {
        // dX dense gemm + dW dense gemm
        return dev.gemm(b, n_in, n_out) + dev.gemm(n_out, n_in, b);
    }
    match fmt {
        ExecFormat::DiagBcsr => {
            // dX: transposed diagonal product (same structure, Apdx A);
            // dW: gradient only on the K diagonals — nnz-proportional
            let dx = linear_fwd(dev, fmt, b, n_in, n_out, sparsity);
            let nnz = (((1.0 - sparsity) * (n_out * n_in) as f64) as usize).max(1);
            let dw = dev.roofline(
                2.0 * b as f64 * nnz as f64,
                2.0 * (b * (n_in + n_out) + nnz) as f64,
                eff::BCSR,
            );
            dx + dw
        }
        ExecFormat::TritonBlock => {
            let dx = linear_fwd(dev, fmt, b, n_in, n_out, sparsity);
            let dw = linear_fwd(dev, fmt, n_out.max(n_in), n_out, n_in, sparsity);
            dx + dw * (b as f64 / n_out.max(n_in) as f64).max(0.25)
        }
        ExecFormat::Csr => {
            let dx = linear_fwd(dev, fmt, b, n_in, n_out, sparsity);
            // dW on nnz coordinates via sampled-dense-dense product
            let nnz = (((1.0 - sparsity) * (n_out * n_in) as f64) as usize).max(1);
            let dw = dev.roofline(
                2.0 * b as f64 * nnz as f64,
                2.0 * (b * (n_in + n_out)) as f64 + 12.0 * nnz as f64,
                eff::CSR,
            );
            dx + dw
        }
        _ => dev.gemm(b, n_in, n_out) + dev.gemm(n_out, n_in, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_gemm_sane() {
        // 768³ gemm at batch 197: ~0.46 GFLOP → tens of microseconds
        let t = A100.gemm(197, 768, 768);
        assert!(t > 1e-6 && t < 1e-3, "t = {}", t);
    }

    /// batch 128 × 197 tokens — the flattened row count the ViT-B linear
    /// layers actually see (tiny b is launch-bound and uninformative).
    const B: usize = 128 * 197;

    #[test]
    fn csr_slower_than_dense_at_moderate_sparsity() {
        // the paper's premise: unstructured sparsity gives no speedup
        let dense = A100.gemm(B, 3072, 768);
        let csr = linear_fwd(&A100, ExecFormat::Csr, B, 3072, 768, 0.6);
        assert!(csr > dense, "csr {} dense {}", csr, dense);
    }

    #[test]
    fn diag_bcsr_beats_dense_at_high_sparsity() {
        let dense = A100.gemm(B, 3072, 768);
        let diag = linear_fwd(&A100, ExecFormat::DiagBcsr, B, 3072, 768, 0.9);
        assert!(diag < dense, "diag {} dense {}", diag, dense);
        assert!(dense / diag > 1.5, "speedup {}", dense / diag);
    }

    #[test]
    fn speedup_grows_with_sparsity() {
        let mut prev = 0.0;
        for &s in &[0.5, 0.6, 0.7, 0.8, 0.9, 0.95] {
            let dense = A100.gemm(B, 3072, 768);
            let diag = linear_fwd(&A100, ExecFormat::DiagBcsr, B, 3072, 768, s);
            let sp = dense / diag;
            assert!(sp >= prev * 0.9, "not monotone at {}: {} vs {}", s, sp, prev);
            prev = sp;
        }
    }

    #[test]
    fn nm24_bounded_speedup() {
        let dense = A100.gemm(B, 768, 768);
        let nm = linear_fwd(&A100, ExecFormat::Nm24, B, 768, 768, 0.5);
        let sp = dense / nm;
        assert!(sp > 1.1 && sp < 2.2, "2:4 speedup {}", sp);
    }

    #[test]
    fn sparse_bwd_cheaper_than_dense_bwd() {
        let sparse = linear_bwd(&A100, ExecFormat::DiagBcsr, B, 3072, 768, 0.9, true);
        let dense = linear_bwd(&A100, ExecFormat::DiagBcsr, B, 3072, 768, 0.9, false);
        assert!(sparse < dense);
    }
}
