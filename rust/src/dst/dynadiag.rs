//! The DynaDiag controller — the paper's primary contribution, L3 side.
//!
//! During training, diagonal topology lives in each layer's trained α
//! vector inside the XLA graph (Eq. 4–5); this controller drives the
//! runtime scalars the graph consumes each step:
//!
//!   * per-layer k budgets (global sparsity → Table 14 distribution →
//!     K_j = (1−S_j)·n_in, optionally ramped by the Table 15 schedule),
//!   * the TopK temperature (cosine-annealed, Fig 8),
//!   * the ℓ1(α) coefficient.
//!
//! After training it *finalizes*: hard-TopK per layer → selected offsets →
//! values extracted from V → `DiagMatrix` (+ BCSR conversion for the
//! execution path) → masks for the Table 16 small-world analysis.

use crate::config::RunConfig;
use crate::sparsity::diagonal::{diag_col, DiagMatrix};
use crate::sparsity::distribution::{allocate, LayerShape};
use crate::sparsity::mask::Mask;
use crate::sparsity::schedule::{sparsity_at, temperature};
use crate::sparsity::topk::{effective_k, hard_topk};
use crate::tensor::Tensor;

/// Per-run controller state.
#[derive(Clone, Debug)]
pub struct DynaDiagController {
    pub layers: Vec<(String, usize, usize)>,
    /// per-layer target sparsity from the distribution scheme
    pub layer_sparsity: Vec<f64>,
    cfg_steps: usize,
    temp_curve: crate::sparsity::schedule::Curve,
    temp_start: f64,
    temp_end: f64,
    sparsity_curve: crate::sparsity::schedule::Curve,
    l1: f64,
}

impl DynaDiagController {
    pub fn new(cfg: &RunConfig, layers: Vec<(String, usize, usize)>) -> DynaDiagController {
        let shapes: Vec<LayerShape> = layers
            .iter()
            .map(|&(_, o, i)| LayerShape { n_out: o, n_in: i })
            .collect();
        let max_s = 1.0 - 1.0 / shapes
            .iter()
            .map(|l| l.n_in)
            .max()
            .unwrap_or(2) as f64;
        let layer_sparsity = allocate(cfg.distribution, &shapes, cfg.sparsity, max_s);
        DynaDiagController {
            layers,
            layer_sparsity,
            cfg_steps: cfg.steps,
            temp_curve: cfg.temp_curve,
            temp_start: cfg.temp_start,
            temp_end: cfg.temp_end,
            sparsity_curve: cfg.sparsity_curve,
            l1: cfg.l1,
        }
    }

    /// Temperature T for this step (Fig 8 schedules). Annealed over the
    /// same 40% window as the sparsity ramp: exploration while diagonals
    /// are being dropped, crisp selection during re-convergence.
    pub fn temperature(&self, step: usize) -> f64 {
        let ramp_end = ((self.cfg_steps as f64 * 0.4) as usize).max(1);
        temperature(
            self.temp_curve,
            step.min(ramp_end),
            ramp_end,
            self.temp_start,
            self.temp_end,
        )
    }

    pub fn l1_coeff(&self) -> f64 {
        self.l1
    }

    /// Per-layer k values for this step. The sparsity ramp (Table 15 /
    /// Fig 8) anneals from *dense* (k ≈ D, every ᾱ saturated at 1 so
    /// gradients reach α through the unsaturated margin as diagonals fall
    /// out of the TopK) down to the target K; Constant pins the target
    /// from step 0 — no exploration, the paper's worst case.
    pub fn kvec(&self, step: usize) -> Vec<f32> {
        self.layers
            .iter()
            .zip(&self.layer_sparsity)
            .map(|(&(_, _, n_in), &s_target)| {
                // ramp to the target over the first 40% of training so the
                // selected topology has the remaining 60% to re-converge
                let ramp_end = (self.cfg_steps as f64 * 0.4) as usize;
                let s = sparsity_at(
                    self.sparsity_curve,
                    step.min(ramp_end),
                    ramp_end.max(1),
                    0.0,
                    s_target,
                );
                (((1.0 - s) * n_in as f64).round() as f32).max(1.0)
            })
            .collect()
    }

    /// Final integer K per layer (for hard selection).
    pub fn final_k(&self, layer: usize) -> usize {
        let (_, _, n_in) = self.layers[layer];
        (((1.0 - self.layer_sparsity[layer]) * n_in as f64).round() as usize)
            .clamp(1, n_in)
    }

    /// Effective active-diagonal count of a layer at a step (Fig 8 metric).
    pub fn effective_diagonals(&self, layer: usize, alpha: &[f32], step: usize) -> usize {
        let k = self.kvec(step)[layer] as f64;
        effective_k(alpha, k, self.temperature(step), 0.5)
    }

    /// Finalize one layer: hard TopK over α → offsets; values from V scaled
    /// by the *final soft ᾱ* so the finalized sparse model computes exactly
    /// what the trained soft model computed (up to the dropped non-top-K
    /// tail). Without the scaling, diagonals that trained at ᾱ ≈ 0 would
    /// re-enter at full strength with never-trained V values (§Perf log).
    pub fn finalize_layer(&self, layer: usize, alpha: &[f32], v_dense: &Tensor) -> DiagMatrix {
        let (_, n_out, n_in) = self.layers[layer];
        assert_eq!(alpha.len(), n_in, "alpha length mismatch");
        assert_eq!(v_dense.shape, vec![n_out, n_in]);
        let k = self.final_k(layer);
        let atilde = crate::sparsity::topk::soft_topk(
            alpha,
            k as f64,
            self.temperature(self.cfg_steps),
        );
        let mut offsets = hard_topk(alpha, k);
        offsets.sort_unstable();
        let mut d = DiagMatrix::new(n_out, n_in, offsets);
        for j in 0..d.k() {
            let off = d.offsets[j];
            let scale = atilde[off] as f32;
            for i in 0..n_out {
                d.values[j][i] = scale * v_dense.at2(i, diag_col(i, off, n_in));
            }
        }
        d
    }

    /// Masks of the finalized topology (Table 16 small-world analysis).
    pub fn finalize_masks(&self, alphas: &[Vec<f32>]) -> Vec<(String, Mask)> {
        self.layers
            .iter()
            .enumerate()
            .map(|(l, (name, n_out, n_in))| {
                let k = self.final_k(l);
                let offsets = hard_topk(&alphas[l], k);
                (
                    name.clone(),
                    crate::sparsity::diagonal::diag_mask(*n_out, *n_in, &offsets),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::schedule::Curve;
    use crate::util::rng::Rng;

    fn controller(sparsity: f64, curve: Curve) -> DynaDiagController {
        let mut cfg = RunConfig::default();
        cfg.sparsity = sparsity;
        cfg.steps = 100;
        cfg.sparsity_curve = curve;
        let layers = vec![
            ("a".to_string(), 32, 32),
            ("b".to_string(), 64, 32),
            ("c".to_string(), 32, 64),
        ];
        DynaDiagController::new(&cfg, layers)
    }

    #[test]
    fn temperature_anneals() {
        let c = controller(0.9, Curve::Cosine);
        // anneals over the first 40% of training, then holds at temp_end
        assert!(c.temperature(0) > c.temperature(20));
        assert!(c.temperature(20) > c.temperature(40));
        assert!((c.temperature(40) - c.temperature(100)).abs() < 1e-9);
        let end = RunConfig::default().temp_end;
        assert!((c.temperature(100) - end).abs() < 1e-9);
    }

    #[test]
    fn kvec_shrinks_toward_target() {
        let c = controller(0.9, Curve::Cosine);
        let k0 = c.kvec(0);
        let k_end = c.kvec(100);
        for (a, b) in k0.iter().zip(&k_end) {
            assert!(a >= b, "k must shrink: {} -> {}", a, b);
        }
        // final k matches the budget
        for l in 0..3 {
            assert!((k_end[l] as usize).abs_diff(c.final_k(l)) <= 1);
        }
    }

    #[test]
    fn constant_curve_pins_target() {
        let c = controller(0.9, Curve::Constant);
        let k0 = c.kvec(0);
        for l in 0..3 {
            assert!((k0[l] as usize).abs_diff(c.final_k(l)) <= 1);
        }
    }

    #[test]
    fn finalize_extracts_topk_diagonals() {
        let c = controller(0.75, Curve::Constant);
        let (_, n_out, n_in) = c.layers[0];
        let mut rng = Rng::new(80);
        let mut alpha = vec![0.0f32; n_in];
        // make offsets 3, 10, 17, ... clearly the best
        let k = c.final_k(0);
        for j in 0..k {
            alpha[(3 + 7 * j) % n_in] = 10.0 + j as f32;
        }
        let v = Tensor::randn(&[n_out, n_in], 1.0, &mut rng);
        let d = c.finalize_layer(0, &alpha, &v);
        assert_eq!(d.k(), k);
        for j in 0..k {
            assert!(d.offsets.contains(&((3 + 7 * j) % n_in)));
        }
        // values come from V scaled by the final soft alpha (saturated = 1
        // for the clearly-selected diagonals in this construction)
        let w = d.to_dense();
        for &off in &d.offsets {
            for i in 0..n_out {
                let c_ = diag_col(i, off, n_in);
                let ratio = w.at2(i, c_) / v.at2(i, c_);
                assert!(
                    (0.0..=1.0 + 1e-5).contains(&(ratio as f64)),
                    "scaled value outside [0, v]: ratio {}",
                    ratio
                );
            }
        }
        // the top-scoring diagonal saturates at exactly alpha=1
        let best_off = (0..n_in).max_by(|&a, &b| {
            alpha[a].partial_cmp(&alpha[b]).unwrap()
        }).unwrap();
        let j_best = d.offsets.iter().position(|&o| o == best_off).unwrap();
        for i in 0..n_out {
            let c_ = diag_col(i, best_off, n_in);
            assert!((d.values[j_best][i] - v.at2(i, c_)).abs() < 1e-5);
        }
    }

    #[test]
    fn finalize_masks_have_budget() {
        let c = controller(0.8, Curve::Constant);
        let alphas: Vec<Vec<f32>> = c
            .layers
            .iter()
            .enumerate()
            .map(|(l, &(_, _, n_in))| {
                let mut rng = Rng::new(l as u64);
                (0..n_in).map(|_| rng.normal_f32(0.0, 1.0)).collect()
            })
            .collect();
        for (l, (_, mask)) in c.finalize_masks(&alphas).iter().enumerate() {
            let (_, n_out, _) = c.layers[l];
            assert_eq!(mask.nnz(), c.final_k(l) * n_out);
        }
    }
}
