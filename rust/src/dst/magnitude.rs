//! Magnitude-family unstructured DST baselines: SET, RigL, MEST.
//!
//! All three share the prune phase (drop the lowest-|w| active weights) and
//! differ in the grow phase:
//!   * SET  — grow uniformly at random (Mocanu et al. 2018)
//!   * RigL — grow the largest-|grad| missing links (Evci et al. 2020);
//!            needs the dense grad probe, grown weights start at zero
//!   * MEST — prune by |w| + γ|grad| (needs grads), grow randomly
//!            (Yuan et al. 2021)

use super::{
    active_by_magnitude, inactive_by_score, nnz_budget, prune_grow, DstMethod,
    GrowAction, LayerUpdate,
};
use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

fn random_init_mask(n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
    Mask::random(n_out, n_in, nnz_budget(n_out, n_in, sparsity), rng)
}

fn prune_count(mask: &Mask, fraction: f64) -> usize {
    ((mask.nnz() as f64 * fraction).round() as usize).min(mask.nnz().saturating_sub(1))
}

/// SET (Sparse Evolutionary Training).
pub struct Set;

impl DstMethod for Set {
    fn name(&self) -> &'static str {
        "SET"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
        random_init_mask(n_out, n_in, sparsity, rng)
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        _grads: Option<&Tensor>,
        fraction: f64,
        rng: &mut Rng,
    ) -> LayerUpdate {
        let k = prune_count(mask, fraction);
        let prune = active_by_magnitude(mask, weights);
        let mut inact: Vec<usize> =
            (0..mask.bits.len()).filter(|&i| !mask.bits[i]).collect();
        rng.shuffle(&mut inact);
        prune_grow(mask, &prune, &inact, k, GrowAction::RandomSmall)
    }
}

/// RigL (Rigging the Lottery).
pub struct RigL;

impl DstMethod for RigL {
    fn name(&self) -> &'static str {
        "RigL"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
        random_init_mask(n_out, n_in, sparsity, rng)
    }

    fn needs_grads(&self) -> bool {
        true
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        grads: Option<&Tensor>,
        fraction: f64,
        _rng: &mut Rng,
    ) -> LayerUpdate {
        let g = grads.expect("RigL needs the dense grad probe");
        let k = prune_count(mask, fraction);
        let prune = active_by_magnitude(mask, weights);
        let grow = inactive_by_score(mask, |i| g.data[i].abs());
        prune_grow(mask, &prune, &grow, k, GrowAction::Zero)
    }
}

/// MEST (Memory-Economic Sparse Training): prune by |w| + γ|g|, grow random.
pub struct Mest {
    pub gamma: f32,
}

impl DstMethod for Mest {
    fn name(&self) -> &'static str {
        "MEST"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
        random_init_mask(n_out, n_in, sparsity, rng)
    }

    fn needs_grads(&self) -> bool {
        true
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        grads: Option<&Tensor>,
        fraction: f64,
        rng: &mut Rng,
    ) -> LayerUpdate {
        let g = grads.expect("MEST needs grads for its prune score");
        let k = prune_count(mask, fraction);
        let mut act: Vec<usize> =
            (0..mask.bits.len()).filter(|&i| mask.bits[i]).collect();
        act.sort_by(|&a, &b| {
            let sa = weights.data[a].abs() + self.gamma * g.data[a].abs();
            let sb = weights.data[b].abs() + self.gamma * g.data[b].abs();
            sa.partial_cmp(&sb).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut inact: Vec<usize> =
            (0..mask.bits.len()).filter(|&i| !mask.bits[i]).collect();
        rng.shuffle(&mut inact);
        prune_grow(mask, &act, &inact, k, GrowAction::RandomSmall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn setup(rng: &mut Rng) -> (Mask, Tensor, Tensor) {
        let mask = Mask::random(12, 10, 40, rng);
        let w = Tensor::randn(&[12, 10], 1.0, rng);
        let g = Tensor::randn(&[12, 10], 1.0, rng);
        (mask, w, g)
    }

    #[test]
    fn all_methods_preserve_budget() {
        forall(
            50,
            30,
            |r| {
                let mut rr = r.fork(1);
                let s = setup(&mut rr);
                let f = 0.05 + 0.4 * r.f64();
                (s, f, r.fork(2))
            },
            |((mask, w, g), f, rng)| {
                let mut rng = rng.clone();
                for m in [
                    &mut Set as &mut dyn DstMethod,
                    &mut RigL,
                    &mut Mest { gamma: 0.1 },
                ] {
                    let grads = if m.needs_grads() { Some(g) } else { None };
                    let up = m.update_layer(mask, w, grads, *f, &mut rng);
                    if up.mask.nnz() != mask.nnz() {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn rigl_grows_highest_gradient_links() {
        let mut rng = Rng::new(51);
        let mut mask = Mask::zeros(4, 4);
        for j in 0..4 {
            mask.set(0, j, true);
        }
        let mut w = Tensor::zeros(&[4, 4]);
        for j in 0..4 {
            *w.at2_mut(0, j) = 0.01 * (j + 1) as f32;
        }
        let mut g = Tensor::zeros(&[4, 4]);
        *g.at2_mut(3, 3) = 100.0; // clearly the best missing link
        let up = RigL.update_layer(&mask, &w, Some(&g), 0.25, &mut rng);
        assert!(up.mask.get(3, 3), "RigL must grow the top-grad link");
        assert!(!up.mask.get(0, 0), "RigL must prune the smallest weight");
        assert_eq!(up.grow_action, GrowAction::Zero);
    }

    #[test]
    fn mest_protects_high_gradient_small_weights() {
        let mut rng = Rng::new(52);
        let mut mask = Mask::zeros(2, 2);
        mask.set(0, 0, true);
        mask.set(0, 1, true);
        let mut w = Tensor::zeros(&[2, 2]);
        *w.at2_mut(0, 0) = 0.01; // small weight, huge grad
        *w.at2_mut(0, 1) = 0.02; // slightly bigger weight, zero grad
        let mut g = Tensor::zeros(&[2, 2]);
        *g.at2_mut(0, 0) = 10.0;
        let up = Mest { gamma: 0.1 }.update_layer(&mask, &w, Some(&g), 0.5, &mut rng);
        assert!(up.mask.get(0, 0), "high-grad small weight must survive MEST");
        assert!(!up.mask.get(0, 1));
    }

    #[test]
    fn set_grows_somewhere_new() {
        let mut rng = Rng::new(53);
        let (mask, w, _) = setup(&mut rng);
        let up = Set.update_layer(&mask, &w, None, 0.3, &mut rng);
        assert!(!up.grown.is_empty());
        for &(i, j) in &up.grown {
            assert!(!mask.get(i, j));
        }
        assert_eq!(up.grow_action, GrowAction::RandomSmall);
    }
}
