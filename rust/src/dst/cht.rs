//! CHT — Cannistraci-Hebb epitopological training (Zhang et al. 2024),
//! implemented at its core idea: *gradient-free* regrowth driven by network
//! topology. Missing links are scored by a bipartite Cannistraci-Hebb
//! length-3 path score (common-neighbour strength), so regrowth needs no
//! dense gradients — the property that makes CHT scalable.
//!
//! Score of missing link (row i, col j):
//!     CH3(i, j) = Σ_{i' ∈ N(j)}  |N(i) ∩ N(i')|  / (1 + |N(i') \ {j}|)
//! where N(·) are bipartite neighbourhoods (cols active in a row / rows
//! active in a col). Paths i→c→i'→j with well-connected intermediates score
//! higher; the denominator penalizes promiscuous hubs, after the CH "local
//! community" normalization.

use super::{active_by_magnitude, nnz_budget, prune_grow, DstMethod, GrowAction, LayerUpdate};
use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct Cht;

/// Row supports as bitset words for fast intersections.
fn row_bitsets(mask: &Mask) -> Vec<Vec<u64>> {
    let words = mask.cols.div_ceil(64);
    let mut rows = vec![vec![0u64; words]; mask.rows];
    for i in 0..mask.rows {
        for j in 0..mask.cols {
            if mask.get(i, j) {
                rows[i][j / 64] |= 1 << (j % 64);
            }
        }
    }
    rows
}

fn intersect_count(a: &[u64], b: &[u64]) -> u32 {
    a.iter().zip(b).map(|(x, y)| (x & y).count_ones()).sum()
}

/// CH3 link score for every missing entry. O(cols · rows_per_col · rows)
/// in the worst case but bitset-accelerated; fine at our layer sizes.
pub fn ch3_scores(mask: &Mask) -> Vec<f32> {
    let rows_bits = row_bitsets(mask);
    let row_deg: Vec<u32> = rows_bits
        .iter()
        .map(|b| b.iter().map(|w| w.count_ones()).sum())
        .collect();
    // rows active per column
    let mut col_rows: Vec<Vec<usize>> = vec![Vec::new(); mask.cols];
    for i in 0..mask.rows {
        for j in 0..mask.cols {
            if mask.get(i, j) {
                col_rows[j].push(i);
            }
        }
    }
    let mut scores = vec![0.0f32; mask.rows * mask.cols];
    for j in 0..mask.cols {
        for i in 0..mask.rows {
            if mask.get(i, j) {
                continue;
            }
            let mut s = 0.0f32;
            for &ip in &col_rows[j] {
                if ip == i {
                    continue;
                }
                let common = intersect_count(&rows_bits[i], &rows_bits[ip]);
                if common > 0 {
                    let external = row_deg[ip].saturating_sub(1); // minus edge to j
                    s += common as f32 / (1.0 + external as f32);
                }
            }
            scores[i * mask.cols + j] = s;
        }
    }
    scores
}

impl DstMethod for Cht {
    fn name(&self) -> &'static str {
        "CHT"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
        // CHT initializes from a correlated-inhomogeneous topology; we use
        // the BSW generator (Apdx I) thinned to budget, falling back to
        // random for very small budgets.
        let nnz = nnz_budget(n_out, n_in, sparsity);
        let k = (nnz / n_out.max(1)).max(1);
        let g = crate::graph::generators::bsw(n_out, n_in, k, 0.2, rng);
        let mut mask = Mask::zeros(n_out, n_in);
        for u in 0..n_out {
            for &v in &g.adj[u] {
                mask.set(u, v - n_out, true);
            }
        }
        // trim/pad to the exact budget
        let mut active: Vec<usize> =
            (0..mask.bits.len()).filter(|&i| mask.bits[i]).collect();
        if active.len() > nnz {
            rng.shuffle(&mut active);
            for &idx in active.iter().take(active.len() - nnz) {
                mask.bits[idx] = false;
            }
        } else {
            let mut inactive: Vec<usize> =
                (0..mask.bits.len()).filter(|&i| !mask.bits[i]).collect();
            rng.shuffle(&mut inactive);
            for &idx in inactive.iter().take(nnz - active.len()) {
                mask.bits[idx] = true;
            }
        }
        mask
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        _grads: Option<&Tensor>,
        fraction: f64,
        rng: &mut Rng,
    ) -> LayerUpdate {
        let k = ((mask.nnz() as f64 * fraction).round() as usize)
            .min(mask.nnz().saturating_sub(1));
        let prune = active_by_magnitude(mask, weights);
        let scores = ch3_scores(mask);
        // break CH ties randomly so zero-score regions don't get row-major bias
        let jitter: Vec<f32> = (0..scores.len()).map(|_| rng.f32() * 1e-6).collect();
        let grow = super::inactive_by_score(mask, |i| scores[i] + jitter[i]);
        prune_grow(mask, &prune, &grow, k, GrowAction::RandomSmall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ch3_prefers_dense_neighbourhoods() {
        // rows 0,1 share many columns; link (0, 5) should outscore a link
        // into an empty region.
        let mut mask = Mask::zeros(4, 8);
        for j in 0..4 {
            mask.set(0, j, true);
            mask.set(1, j, true);
        }
        mask.set(1, 5, true); // row 1 reaches col 5
        let scores = ch3_scores(&mask);
        let near = scores[5]; // (0,5): path 0→{0..3}→1→5
        let far = scores[7]; // (0,7): nothing reaches col 7
        assert!(near > far, "near {} far {}", near, far);
        assert_eq!(far, 0.0);
    }

    #[test]
    fn cht_budget_preserved_and_gradient_free() {
        let mut rng = Rng::new(70);
        let mut m = Cht;
        assert!(!m.needs_grads(), "CHT must be gradient-free");
        let mask = m.init_mask(16, 16, 0.8, &mut rng);
        assert_eq!(mask.nnz(), nnz_budget(16, 16, 0.8));
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let up = m.update_layer(&mask, &w, None, 0.3, &mut rng);
        assert_eq!(up.mask.nnz(), mask.nnz());
    }

    #[test]
    fn scores_zero_on_active_entries() {
        let mut rng = Rng::new(71);
        let mask = Mask::random(10, 10, 30, &mut rng);
        let scores = ch3_scores(&mask);
        for (i, &s) in scores.iter().enumerate() {
            if mask.bits[i] {
                assert_eq!(s, 0.0);
            }
        }
    }
}
