//! Wanda one-shot pruning (Sun et al. 2023) — the Table 13 comparison point.
//!
//! Wanda scores weight (i, j) by |W_ij| · ‖X_j‖₂ where X_j is the j-th input
//! feature over a calibration set, pruning per-*row* (per output) — no
//! retraining. Our layers sit behind LayerNorm so E‖X_j‖ is near-uniform;
//! we expose the input-norm hook anyway (callers estimate feature norms
//! from calibration batches of the layer's *inputs* when available, or pass
//! None to degenerate to per-row magnitude pruning — documented in
//! DESIGN.md §6).

use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;

/// Prune `w` to `sparsity` with Wanda's per-row criterion.
/// `input_norms`: optional ‖X_j‖₂ per input feature (len = cols).
pub fn wanda_prune(w: &Tensor, input_norms: Option<&[f32]>, sparsity: f64) -> Mask {
    assert_eq!(w.rank(), 2);
    let (rows, cols) = (w.rows(), w.cols());
    let keep_per_row =
        (((1.0 - sparsity) * cols as f64).round() as usize).clamp(1, cols);
    let mut mask = Mask::zeros(rows, cols);
    let mut scored: Vec<(f32, usize)> = Vec::with_capacity(cols);
    for i in 0..rows {
        scored.clear();
        for j in 0..cols {
            let norm = input_norms.map(|n| n[j]).unwrap_or(1.0);
            scored.push((w.at2(i, j).abs() * norm, j));
        }
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, j) in scored.iter().take(keep_per_row) {
            mask.set(i, j, true);
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn per_row_budget_exact() {
        let mut rng = Rng::new(90);
        let w = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let m = wanda_prune(&w, None, 0.75);
        for c in m.row_nnz() {
            assert_eq!(c, 4);
        }
    }

    #[test]
    fn keeps_largest_scored() {
        let mut w = Tensor::zeros(&[1, 4]);
        w.data.copy_from_slice(&[0.1, 0.9, 0.5, 0.2]);
        let m = wanda_prune(&w, None, 0.5);
        assert!(m.get(0, 1) && m.get(0, 2));
        assert!(!m.get(0, 0) && !m.get(0, 3));
    }

    #[test]
    fn input_norms_change_ranking() {
        let mut w = Tensor::zeros(&[1, 4]);
        w.data.copy_from_slice(&[0.1, 0.9, 0.5, 0.2]);
        // huge norm on feature 0 promotes the small weight
        let norms = [100.0f32, 1.0, 1.0, 1.0];
        let m = wanda_prune(&w, Some(&norms), 0.5);
        assert!(m.get(0, 0) && m.get(0, 1));
    }
}
