//! Structured DST baselines: SRigL (N:M), DSB (blocks), PixelatedBFly
//! (static butterfly), DiagHeur (heuristic diagonals, Apdx H).

use super::{DstMethod, GrowAction, LayerUpdate};
use crate::sparsity::diagonal::{diag_count, diag_mask, DiagMatrix};
use crate::sparsity::mask::Mask;
use crate::sparsity::patterns;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// SRigL: dynamic sparse training constrained to N:M patterns (Lasby et al.).
/// At each update the per-row groups re-select their N survivors by a
/// combined score: |w| on active coordinates, |grad| on missing ones —
/// RigL's criteria projected onto the N:M constraint set.
pub struct SRigL {
    pub group: usize,
}

impl DstMethod for SRigL {
    fn name(&self) -> &'static str {
        "SRigL"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
        let (n, m) = patterns::nm_for_sparsity(self.group, sparsity);
        patterns::nm_mask(n_out, n_in, n, m, None, rng)
    }

    fn needs_grads(&self) -> bool {
        true
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        grads: Option<&Tensor>,
        fraction: f64,
        rng: &mut Rng,
    ) -> LayerUpdate {
        let g = grads.expect("SRigL needs grads");
        let sparsity = mask.sparsity();
        let (n, m) = patterns::nm_for_sparsity(self.group, sparsity);
        // combined score; damp missing-link scores by the update fraction so
        // topology moves gradually like RigL rather than thrashing
        let scores: Vec<f32> = (0..mask.bits.len())
            .map(|i| {
                if mask.bits[i] {
                    weights.data[i].abs()
                } else {
                    (fraction as f32) * g.data[i].abs()
                }
            })
            .collect();
        let new_mask = patterns::nm_mask(mask.rows, mask.cols, n, m, Some(&scores), rng);
        let grown = new_mask
            .active_indices()
            .into_iter()
            .filter(|&(i, j)| !mask.get(i, j))
            .collect();
        LayerUpdate { mask: new_mask, grown, grow_action: GrowAction::Zero }
    }
}

/// DSB (Dynamic Sparse Block): prune lowest-|w| blocks, grow highest-|grad|
/// blocks (Jiang et al. 2022).
pub struct Dsb {
    pub bs: usize,
}

impl Dsb {
    fn block_scores(&self, rows: usize, cols: usize, data: &[f32], active: bool, mask: &Mask) -> Vec<f32> {
        let nbr = rows.div_ceil(self.bs);
        let nbc = cols.div_ceil(self.bs);
        let mut scores = vec![0.0f32; nbr * nbc];
        let mut counts = vec![0usize; nbr * nbc];
        for i in 0..rows {
            for j in 0..cols {
                let b = (i / self.bs) * nbc + j / self.bs;
                if mask.get(i, j) == active {
                    scores[b] += data[i * cols + j].abs();
                    counts[b] += 1;
                }
            }
        }
        for (s, &c) in scores.iter_mut().zip(&counts) {
            if c > 0 {
                *s /= c as f32;
            }
        }
        scores
    }
}

impl DstMethod for Dsb {
    fn name(&self) -> &'static str {
        "DSB"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
        let active = patterns::blocks_for_sparsity(n_out, n_in, self.bs, sparsity);
        patterns::block_mask(n_out, n_in, self.bs, active, None, rng)
    }

    fn needs_grads(&self) -> bool {
        true
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        grads: Option<&Tensor>,
        fraction: f64,
        _rng: &mut Rng,
    ) -> LayerUpdate {
        let g = grads.expect("DSB needs grads");
        let (rows, cols) = (mask.rows, mask.cols);
        let nbc = cols.div_ceil(self.bs);
        let w_scores = self.block_scores(rows, cols, &weights.data, true, mask);
        let g_scores = self.block_scores(rows, cols, &g.data, false, mask);
        // current active blocks
        let active_blocks: Vec<usize> = (0..w_scores.len())
            .filter(|&b| {
                let (br, bc) = (b / nbc, b % nbc);
                mask.get(br * self.bs, (bc * self.bs).min(cols - 1))
            })
            .collect();
        let k = ((active_blocks.len() as f64 * fraction).round() as usize)
            .min(active_blocks.len().saturating_sub(1));
        // prune k lowest-|w| active blocks
        let mut by_w = active_blocks.clone();
        by_w.sort_by(|&a, &b| w_scores[a].partial_cmp(&w_scores[b]).unwrap());
        let pruned: std::collections::HashSet<usize> =
            by_w.iter().take(k).cloned().collect();
        // grow k highest-|g| inactive blocks
        let mut inactive: Vec<usize> = (0..w_scores.len())
            .filter(|b| !active_blocks.contains(b))
            .collect();
        inactive.sort_by(|&a, &b| g_scores[b].partial_cmp(&g_scores[a]).unwrap());
        let grown_blocks: Vec<usize> = inactive.into_iter().take(k).collect();

        let mut new_mask = mask.clone();
        let mut grown = Vec::new();
        for &b in &pruned {
            let (br, bc) = (b / nbc, b % nbc);
            for i in br * self.bs..((br + 1) * self.bs).min(rows) {
                for j in bc * self.bs..((bc + 1) * self.bs).min(cols) {
                    new_mask.set(i, j, false);
                }
            }
        }
        for &b in &grown_blocks {
            let (br, bc) = (b / nbc, b % nbc);
            for i in br * self.bs..((br + 1) * self.bs).min(rows) {
                for j in bc * self.bs..((bc + 1) * self.bs).min(cols) {
                    if !new_mask.get(i, j) {
                        new_mask.set(i, j, true);
                        grown.push((i, j));
                    }
                }
            }
        }
        LayerUpdate { mask: new_mask, grown, grow_action: GrowAction::Zero }
    }
}

/// Pixelated Butterfly: fixed block-butterfly support, no topology updates
/// (static sparse training, Dao et al. 2021).
pub struct PixelatedBFly {
    pub bs: usize,
}

impl DstMethod for PixelatedBFly {
    fn name(&self) -> &'static str {
        "PixelatedBFly"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, _rng: &mut Rng) -> Mask {
        patterns::butterfly_mask(n_out, n_in, self.bs, sparsity)
    }

    fn is_static(&self) -> bool {
        true
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        _weights: &Tensor,
        _grads: Option<&Tensor>,
        _fraction: f64,
        _rng: &mut Rng,
    ) -> LayerUpdate {
        LayerUpdate { mask: mask.clone(), grown: vec![], grow_action: GrowAction::KeepValue }
    }
}

/// DiagHeur (Apdx H): RigL-style decay/regrow at *diagonal* granularity —
/// prune the lowest mean-|w| selected diagonals, regrow random new offsets.
/// The paper's ablation showing that diagonal sparsity *without* the
/// differentiable TopK underperforms DynaDiag.
#[derive(Default)]
pub struct DiagHeur {
    /// per-layer selected offsets keyed by (rows, cols) identity — the
    /// trainer calls methods layer-by-layer in a stable order, so we key by
    /// call sequence instead (reset per init).
    states: Vec<Vec<usize>>,
    init_calls: usize,
    update_calls: usize,
}

impl DstMethod for DiagHeur {
    fn name(&self) -> &'static str {
        "DiagHeur"
    }

    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask {
        let k = diag_count(n_in, sparsity);
        let offsets = rng.choose_k(n_in, k);
        let mask = diag_mask(n_out, n_in, &offsets);
        self.states.push(offsets);
        self.init_calls += 1;
        mask
    }

    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        _grads: Option<&Tensor>,
        fraction: f64,
        rng: &mut Rng,
    ) -> LayerUpdate {
        let slot = self.update_calls % self.states.len().max(1);
        self.update_calls += 1;
        let offsets = self.states[slot].clone();
        let d = DiagMatrix::from_dense(weights, offsets.clone())
            .expect("weights shape mismatch");
        let mags = d.diag_magnitudes();
        let k = ((offsets.len() as f64 * fraction).round() as usize)
            .min(offsets.len().saturating_sub(1));
        // prune k lowest-magnitude diagonals
        let mut order: Vec<usize> = (0..offsets.len()).collect();
        order.sort_by(|&a, &b| mags[a].partial_cmp(&mags[b]).unwrap());
        let pruned: std::collections::HashSet<usize> =
            order.iter().take(k).cloned().collect();
        let mut kept: Vec<usize> = offsets
            .iter()
            .enumerate()
            .filter(|(j, _)| !pruned.contains(j))
            .map(|(_, &o)| o)
            .collect();
        // grow k random new offsets
        let in_use: std::collections::HashSet<usize> = kept.iter().cloned().collect();
        let free: Vec<usize> =
            (0..mask.cols).filter(|o| !in_use.contains(o)).collect();
        let mut grown_offsets = Vec::new();
        if !free.is_empty() {
            for idx in rng.choose_k(free.len(), k.min(free.len())) {
                grown_offsets.push(free[idx]);
            }
        }
        kept.extend(&grown_offsets);
        self.states[slot] = kept.clone();
        let new_mask = diag_mask(mask.rows, mask.cols, &kept);
        let grown = grown_offsets
            .iter()
            .flat_map(|&off| {
                (0..mask.rows)
                    .map(move |i| (i, crate::sparsity::diagonal::diag_col(i, off, mask.cols)))
            })
            .collect();
        LayerUpdate { mask: new_mask, grown, grow_action: GrowAction::RandomSmall }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srigl_mask_is_nm() {
        let mut rng = Rng::new(60);
        let mut m = SRigL { group: 8 };
        let mask = m.init_mask(16, 32, 0.75, &mut rng);
        for i in 0..16 {
            for g in 0..4 {
                let cnt = (g * 8..(g + 1) * 8).filter(|&j| mask.get(i, j)).count();
                assert_eq!(cnt, 2, "2:8 expected");
            }
        }
        // after update, still N:M
        let w = Tensor::randn(&[16, 32], 1.0, &mut rng);
        let g = Tensor::randn(&[16, 32], 1.0, &mut rng);
        let up = m.update_layer(&mask, &w, Some(&g), 0.3, &mut rng);
        for i in 0..16 {
            for gi in 0..4 {
                let cnt =
                    (gi * 8..(gi + 1) * 8).filter(|&j| up.mask.get(i, j)).count();
                assert_eq!(cnt, 2);
            }
        }
    }

    #[test]
    fn dsb_moves_whole_blocks() {
        let mut rng = Rng::new(61);
        let mut m = Dsb { bs: 4 };
        let mask = m.init_mask(16, 16, 0.75, &mut rng);
        let nnz0 = mask.nnz();
        assert_eq!(nnz0 % 16, 0, "block-aligned nnz");
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let g = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let up = m.update_layer(&mask, &w, Some(&g), 0.5, &mut rng);
        assert_eq!(up.mask.nnz(), nnz0, "block budget preserved");
        assert_eq!(up.grown.len() % 16, 0, "grown in whole blocks");
    }

    #[test]
    fn pbfly_is_static() {
        let mut rng = Rng::new(62);
        let mut m = PixelatedBFly { bs: 4 };
        let mask = m.init_mask(32, 32, 0.8, &mut rng);
        assert!(m.is_static());
        let w = Tensor::randn(&[32, 32], 1.0, &mut rng);
        let up = m.update_layer(&mask, &w, None, 0.3, &mut rng);
        assert_eq!(up.mask, mask);
        assert!(up.grown.is_empty());
    }

    #[test]
    fn diagheur_keeps_diagonal_structure_and_budget() {
        let mut rng = Rng::new(63);
        let mut m = DiagHeur::default();
        let mask = m.init_mask(16, 16, 0.75, &mut rng);
        let k0 = diag_count(16, 0.75);
        assert_eq!(mask.nnz(), k0 * 16);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let up = m.update_layer(&mask, &w, None, 0.5, &mut rng);
        assert_eq!(up.mask.nnz(), k0 * 16, "diagonal count preserved");
        // still expressible as whole diagonals: every row has k0 nnz
        for c in up.mask.row_nnz() {
            assert_eq!(c, k0);
        }
    }

    #[test]
    fn diagheur_prunes_weak_diagonals() {
        let mut rng = Rng::new(64);
        let mut m = DiagHeur::default();
        let mask = m.init_mask(8, 8, 0.5, &mut rng);
        let offsets = m.states[0].clone();
        // make one diagonal clearly weakest
        let mut w = Tensor::zeros(&[8, 8]);
        for (j, &off) in offsets.iter().enumerate() {
            for i in 0..8 {
                let c = crate::sparsity::diagonal::diag_col(i, off, 8);
                *w.at2_mut(i, c) = if j == 0 { 0.001 } else { 1.0 };
            }
        }
        let weak = offsets[0];
        let up = m.update_layer(&mask, &w, None, 0.26, &mut rng);
        let still_there = m.states[0].contains(&weak);
        // weak diagonal should be pruned (unless randomly regrown)
        if still_there {
            assert!(up.grown.iter().any(|&(i, j)| {
                crate::sparsity::diagonal::owner_offset(i, j, 8) == weak
            }));
        }
    }
}
