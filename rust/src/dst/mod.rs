//! Dynamic Sparse Training methods (Sec 2.2 / 4.1 baselines + DiagHeur).
//!
//! Every masked baseline implements [`DstMethod`]: the trainer calls
//! `init_mask` once per layer, then `update_layer` at each topology-update
//! step (cadence ΔT, cosine-decayed fraction — RigL's recipe, shared by all
//! the prune-and-regrow methods). The trainer owns weights host-side between
//! XLA steps; `GrowAction` tells it how to initialize regrown weights.
//!
//! DynaDiag itself is *not* a masked method — its topology lives in the
//! trained α vector (see [`dynadiag`]) — but its controller shares the
//! budget/schedule plumbing here.

pub mod cht;
pub mod dynadiag;
pub mod magnitude;
pub mod structured;
pub mod wanda;

use crate::config::{MethodKind, RunConfig};
use crate::sparsity::mask::Mask;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// How regrown coordinates should be initialized by the trainer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GrowAction {
    /// RigL: new weights start at exactly zero
    Zero,
    /// SET-style: small random init
    RandomSmall,
    /// keep whatever value the dense buffer holds (block/pattern rebuilds)
    KeepValue,
}

/// Result of one layer topology update.
#[derive(Clone, Debug)]
pub struct LayerUpdate {
    pub mask: Mask,
    /// coordinates newly activated this update
    pub grown: Vec<(usize, usize)>,
    pub grow_action: GrowAction,
}

/// A masked DST baseline.
pub trait DstMethod {
    fn name(&self) -> &'static str;

    /// Initial topology for one layer at its sparsity budget.
    fn init_mask(&mut self, n_out: usize, n_in: usize, sparsity: f64, rng: &mut Rng) -> Mask;

    /// Whether `update_layer` wants dense gradients (triggers a grad-probe
    /// artifact call at update steps).
    fn needs_grads(&self) -> bool {
        false
    }

    /// Prune-and-regrow one layer. `fraction` is the RigL-style update
    /// fraction (share of active weights to move). `grads` is Some iff
    /// `needs_grads`.
    fn update_layer(
        &mut self,
        mask: &Mask,
        weights: &Tensor,
        grads: Option<&Tensor>,
        fraction: f64,
        rng: &mut Rng,
    ) -> LayerUpdate;

    /// Static methods (PixelatedBFly) skip updates entirely.
    fn is_static(&self) -> bool {
        false
    }
}

/// Instantiate the method named in the config.
pub fn build_method(cfg: &RunConfig) -> Option<Box<dyn DstMethod>> {
    match cfg.method {
        MethodKind::Set => Some(Box::new(magnitude::Set)),
        MethodKind::RigL => Some(Box::new(magnitude::RigL)),
        MethodKind::Mest => Some(Box::new(magnitude::Mest { gamma: 0.1 })),
        MethodKind::Cht => Some(Box::new(cht::Cht)),
        MethodKind::SRigL => Some(Box::new(structured::SRigL { group: cfg.nm_group })),
        MethodKind::Dsb => Some(Box::new(structured::Dsb { bs: cfg.block_size })),
        MethodKind::PixelatedBFly => {
            Some(Box::new(structured::PixelatedBFly { bs: cfg.block_size }))
        }
        MethodKind::DiagHeur => Some(Box::new(structured::DiagHeur::default())),
        // Dense / DynaDiag / Wanda don't run the masked prune-grow loop
        MethodKind::Dense | MethodKind::DynaDiag | MethodKind::Wanda => None,
    }
}

/// Is `step` a topology-update step under the config cadence?
pub fn is_update_step(cfg: &RunConfig, step: usize) -> bool {
    step > 0
        && step % cfg.update_every == 0
        && (step as f64) < cfg.update_until * cfg.steps as f64
}

// ---------------------------------------------------------------------------
// shared prune/grow helpers
// ---------------------------------------------------------------------------

/// Indices of active entries sorted ascending by |w| (prune candidates).
pub fn active_by_magnitude(mask: &Mask, w: &Tensor) -> Vec<usize> {
    let mut act: Vec<usize> = (0..mask.bits.len()).filter(|&i| mask.bits[i]).collect();
    act.sort_by(|&a, &b| {
        w.data[a]
            .abs()
            .partial_cmp(&w.data[b].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    act
}

/// Indices of inactive entries sorted descending by score (grow candidates).
pub fn inactive_by_score(mask: &Mask, score: impl Fn(usize) -> f32) -> Vec<usize> {
    let mut inact: Vec<usize> =
        (0..mask.bits.len()).filter(|&i| !mask.bits[i]).collect();
    inact.sort_by(|&a, &b| {
        score(b).partial_cmp(&score(a)).unwrap_or(std::cmp::Ordering::Equal)
    });
    inact
}

/// Generic prune-k/grow-k on element granularity; preserves nnz exactly.
pub fn prune_grow(
    mask: &Mask,
    prune_order: &[usize],
    grow_order: &[usize],
    k: usize,
    grow_action: GrowAction,
) -> LayerUpdate {
    let k = k.min(prune_order.len()).min(grow_order.len());
    let mut new_mask = mask.clone();
    for &idx in prune_order.iter().take(k) {
        new_mask.bits[idx] = false;
    }
    let mut grown = Vec::with_capacity(k);
    let mut taken = 0;
    for &idx in grow_order {
        if taken == k {
            break;
        }
        if !new_mask.bits[idx] {
            new_mask.bits[idx] = true;
            grown.push((idx / mask.cols, idx % mask.cols));
            taken += 1;
        }
    }
    // if grow candidates ran short (tiny layers), re-activate pruned ones
    let mut i = 0;
    while taken < k && i < prune_order.len() {
        let idx = prune_order[i];
        if !new_mask.bits[idx] {
            new_mask.bits[idx] = true;
            taken += 1;
        }
        i += 1;
    }
    LayerUpdate { mask: new_mask, grown, grow_action }
}

/// nnz for a (rows, cols, sparsity) budget, always >= 1.
pub fn nnz_budget(rows: usize, cols: usize, sparsity: f64) -> usize {
    (((1.0 - sparsity) * (rows * cols) as f64).round() as usize).clamp(1, rows * cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_step_cadence() {
        let mut cfg = RunConfig::default();
        cfg.steps = 400;
        cfg.update_every = 50;
        cfg.update_until = 0.75;
        assert!(!is_update_step(&cfg, 0));
        assert!(is_update_step(&cfg, 50));
        assert!(!is_update_step(&cfg, 51));
        assert!(is_update_step(&cfg, 250));
        assert!(!is_update_step(&cfg, 300)); // past 75% of training
    }

    #[test]
    fn prune_grow_preserves_nnz() {
        let mut rng = Rng::new(1);
        let mask = Mask::random(8, 8, 20, &mut rng);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let prune = active_by_magnitude(&mask, &w);
        let grow = inactive_by_score(&mask, |i| w.data[i].abs());
        let up = prune_grow(&mask, &prune, &grow, 5, GrowAction::Zero);
        assert_eq!(up.mask.nnz(), 20);
        assert_eq!(up.grown.len(), 5);
        for &(i, j) in &up.grown {
            assert!(up.mask.get(i, j));
            assert!(!mask.get(i, j), "grown coord was already active");
        }
    }

    #[test]
    fn prune_order_is_magnitude_ascending() {
        let mut rng = Rng::new(2);
        let mask = Mask::ones(4, 4);
        let w = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let order = active_by_magnitude(&mask, &w);
        for pair in order.windows(2) {
            assert!(w.data[pair[0]].abs() <= w.data[pair[1]].abs());
        }
    }
}
