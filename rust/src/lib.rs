//! # DynaDiag — Dynamic Sparse Training of Diagonally Sparse Networks
//!
//! Rust + JAX + Pallas reproduction of Tyagi et al., ICML 2025 (DESIGN.md).
//!
//! Three layers:
//! * **L3 (this crate)** — the training coordinator: DST methods, schedules,
//!   BCSR conversion, experiment harness. Owns the step loop; Python never
//!   runs at training time.
//! * **L2** — JAX models AOT-lowered to `artifacts/*.hlo.txt`
//!   (`python/compile/`), executed through [`runtime`].
//! * **L1** — Pallas kernels for the diagonal-sparse products, lowered into
//!   the same artifacts.

pub mod bcsr;
pub mod cli;
pub mod config;
pub mod data;
pub mod dst;
pub mod experiments;
pub mod graph;
pub mod perfmodel;
pub mod runtime;
pub mod sparsity;
pub mod stats;
pub mod tensor;
pub mod train;
pub mod util;
