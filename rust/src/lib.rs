//! # DynaDiag — Dynamic Sparse Training of Diagonally Sparse Networks
//!
//! Rust reproduction of Tyagi et al., ICML 2025 (see `PAPER.md` and
//! `docs/ARCHITECTURE.md` at the repository root).
//!
//! ## Layers
//!
//! * **L3 (this crate)** — the training coordinator: DST methods, schedules,
//!   BCSR conversion, experiment harness. Owns the step loop; Python never
//!   runs at training time.
//! * **L2** — JAX models AOT-lowered to `artifacts/*.hlo.txt`
//!   (`python/compile/`), executed through [`runtime`]'s `XlaBackend`.
//! * **L1** — the diagonal-sparse products. Two interchangeable
//!   implementations: Pallas kernels lowered into the same artifacts, and
//!   the native CPU kernels in [`kernels`] (offset-major diagonal SpMM,
//!   blocked dense GEMM, BCSR SpMM) behind [`runtime`]'s `NativeBackend` —
//!   which trains and serves end-to-end with **no** artifacts directory.
//!
//! ## Quick taste
//!
//! The diagonal algebra is self-contained and runs anywhere:
//!
//! ```
//! use dynadiag::sparsity::diagonal::{diag_count, DiagMatrix};
//! use dynadiag::tensor::Tensor;
//!
//! // 90% sparsity on a 768-wide layer keeps K = 77 of 768 diagonals
//! assert_eq!(diag_count(768, 0.9), 77);
//!
//! // a 4x4 matrix holding its main diagonal (offset 0) and offset 1
//! let mut d = DiagMatrix::new(4, 4, vec![0, 1]);
//! for i in 0..4 {
//!     d.values[0][i] = 1.0; // main diagonal
//!     d.values[1][i] = 2.0; // wrapped superdiagonal
//! }
//! let x = Tensor::ones(&[1, 4]);
//! let y = d.matmul_t(&x).unwrap(); // y = x @ W.T through the diag algebra
//! assert_eq!(y.data, vec![3.0; 4]);
//! assert_eq!(d.to_dense().nnz(), 8);
//! ```
//!
//! Training runs route through [`train::Trainer`], which drives either
//! backend through the named-buffer artifact contract documented in
//! `docs/ARCHITECTURE.md`. Online inference routes through [`serve`]: a
//! dynamic micro-batcher coalescing single-sample requests onto the
//! variable-batch diagonal forward in [`runtime::infer`], scaled across
//! cores by the multi-shard runtime in [`serve::shard`]
//! (`serve --shards N`). Trained models and training state persist through
//! [`artifact`]: the versioned, checksummed `DDIAG` container behind
//! `dynadiag export`, `serve --model <file>`, and
//! `train --checkpoint-every/--resume`.

// Style lints we deliberately opt out of, crate-wide, so the CI clippy
// gate (`cargo clippy -- -D warnings`) stays about correctness: numeric
// kernel code is full of short names and index loops by design, and the
// checkpoint/config codecs assign field-by-field on top of Default.
#![allow(
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::needless_range_loop,
    clippy::type_complexity,
    clippy::field_reassign_with_default,
    clippy::assign_op_pattern,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::manual_range_contains
)]

pub mod analysis;
pub mod artifact;
pub mod bcsr;
pub mod cli;
pub mod config;
pub mod data;
pub mod dst;
pub mod experiments;
pub mod graph;
pub mod kernels;
pub mod obs;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod sparsity;
pub mod stats;
pub mod tensor;
pub mod train;
pub mod util;
