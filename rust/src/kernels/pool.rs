//! Minimal data-parallel substrate for the native kernels (rayon is
//! unavailable offline; `std::thread::scope` keeps this dependency-free and
//! unsafe-free).
//!
//! The one primitive every kernel needs is "split an output buffer into
//! disjoint row chunks and fill them from worker threads". Inputs are shared
//! immutably; outputs are partitioned with `split_at_mut`, so there is no
//! aliasing and no locking on the hot path.

use std::sync::OnceLock;

/// Worker count: `DYNADIAG_THREADS` env override, else available
/// parallelism capped at 8 (the kernel shapes here stop scaling past that).
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("DYNADIAG_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8)
    })
}

/// Partition `data` (logically `rows × row_len`) into contiguous row chunks
/// and run `f(first_row, chunk)` on each chunk, in parallel when the row
/// count justifies the thread spawn cost (`min_rows_per_thread` is the
/// grain). Falls back to a single inline call for small work.
pub fn parallel_rows<T, F>(data: &mut [T], row_len: usize, min_rows_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    if row_len == 0 || rows * row_len != data.len() {
        // not row-shaped: run inline rather than guess a partition
        f(0, data);
        return;
    }
    let threads = num_threads()
        .min(rows / min_rows_per_thread.max(1))
        .max(1);
    if threads <= 1 {
        f(0, data);
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = data;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = chunk_rows.min(rows - row0) * row_len;
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            let first = row0;
            scope.spawn(move || f(first, head));
            row0 += take / row_len;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_row_exactly_once() {
        let rows = 37;
        let row_len = 5;
        let mut data = vec![0u32; rows * row_len];
        parallel_rows(&mut data, row_len, 1, |first, chunk| {
            for (r, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v += (first + r) as u32 + 1;
                }
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / row_len) as u32 + 1, "row {}", i / row_len);
        }
    }

    #[test]
    fn small_work_runs_inline() {
        let mut data = vec![0u8; 6];
        parallel_rows(&mut data, 3, 100, |first, chunk| {
            assert_eq!(first, 0);
            assert_eq!(chunk.len(), 6);
            chunk.fill(9);
        });
        assert!(data.iter().all(|&v| v == 9));
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let mut empty: Vec<f32> = Vec::new();
        parallel_rows(&mut empty, 4, 1, |_, _| {});
        let mut flat = vec![1.0f32; 8];
        parallel_rows(&mut flat, 0, 1, |_, chunk| chunk.fill(2.0));
        assert!(flat.iter().all(|&v| v == 2.0));
    }
}
