//! Persistent data-parallel substrate for the native kernels (rayon is
//! unavailable offline; this is a std-only worker pool).
//!
//! The seed implementation spawned fresh OS threads inside every
//! `parallel_rows` call (`std::thread::scope`), which put a thread
//! create+join on the critical path of every kernel launch. This version
//! keeps a **persistent pool**: `num_threads() - 1` long-lived workers
//! parked on a condvar, woken per dispatch, with the calling thread
//! participating as the extra worker. Synchronization is one mutex-guarded
//! job slot:
//!
//! * a **generation counter** identifies the current job, so a worker that
//!   wakes late (or spuriously) can never re-run tasks from a finished
//!   dispatch;
//! * tasks are claimed from a shared cursor (`next_task`), giving dynamic
//!   load balancing across uneven chunks;
//! * `remaining` counts unfinished tasks; the dispatcher blocks on it
//!   before returning, which is the barrier that makes the borrow-erasure
//!   below sound;
//! * a `busy` flag keeps one job in the slot at a time; a dispatcher that
//!   finds the pool occupied (e.g. parallel test threads, concurrent
//!   experiment cells) falls back to scoped threads for that one job, so
//!   concurrent dispatches keep their parallelism instead of idling.
//!
//! The one `unsafe` in the crate's kernel layer lives here: the dispatched
//! closure is lifetime-erased to a raw pointer so the long-lived workers
//! can call it. This is sound because `dispatch` does not return until
//! every task has finished (`remaining == 0`), so the closure and the
//! buffers it borrows strictly outlive every use; workers hold the job
//! only as a raw pointer, never as a reference, between calls.
//!
//! Work is sized by a **flop-based grain**: callers pass the approximate
//! flops per row, and the pool decides between running inline (small
//! work), or splitting into up to `num_threads()` chunks of at least
//! [`TASK_GRAIN_FLOPS`] each. The grain is deliberately *ISA-blind*: the
//! dispatched microkernel lane width (`kernels::microkernel`) never enters
//! the chunking decision, so a given shape partitions identically under
//! `DYNADIAG_ISA=scalar` and `=avx2`/`=neon` — which is what lets the
//! cross-ISA parity harness compare parallel runs bitwise.
//! Set `DYNADIAG_THREADS=1` for fully deterministic single-thread runs. Every run is deterministic for a
//! *fixed* thread count (tasks own disjoint output rows, claim order never
//! affects results); across different thread counts, all kernels are
//! bit-identical except `diag::grad_values`'s batch-split path, whose
//! partial-sum reduction width follows the worker count.

use std::cell::Cell;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Minimum flops a parallel task should amortize the wakeup cost over.
/// Crossing a condvar wake is a few microseconds; at ~1 GFLOP/s scalar
/// throughput that is ~10k flops, so 64k keeps the overhead under ~10%.
pub const TASK_GRAIN_FLOPS: usize = 64 * 1024;

/// Default ceiling on the worker count when `DYNADIAG_THREADS` is unset:
/// the kernel shapes here stop scaling past 8 cores.
const DEFAULT_MAX_THREADS: usize = 8;

/// Worker count. Default: available parallelism capped at
/// `DEFAULT_MAX_THREADS` (8). `DYNADIAG_THREADS` overrides the cap in
/// either direction — it may *raise* the count past 8 on larger machines.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("DYNADIAG_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(DEFAULT_MAX_THREADS)
    })
}

thread_local! {
    /// Per-dispatcher-thread parallelism budget (0 = uncapped). Serving
    /// shards set this to their core share so N shards dispatching kernels
    /// concurrently fan out to ≈ `num_threads()` tasks total instead of
    /// N × `num_threads()` (oversubscription turns into context-switch
    /// thrash, not throughput).
    static LOCAL_THREAD_CAP: Cell<usize> = const { Cell::new(0) };
}

/// Cap the parallelism of dispatches issued *from the calling thread* to
/// `cap` tasks (`0` lifts the cap). The sharded serving runtime calls this
/// once per shard thread with `num_threads() / shards`; a cap of 1 makes
/// every kernel launched from this thread run inline — no pool wakeups on
/// a shard that owns a single core.
pub fn set_local_thread_cap(cap: usize) {
    LOCAL_THREAD_CAP.with(|c| c.set(cap));
}

/// The calling thread's effective parallelism: [`num_threads`] bounded by
/// [`set_local_thread_cap`]. Every grain decision in this module and the
/// kernel layer sizes against this, not the global count.
pub fn effective_threads() -> usize {
    let cap = LOCAL_THREAD_CAP.with(|c| c.get());
    if cap == 0 {
        num_threads()
    } else {
        cap.min(num_threads()).max(1)
    }
}

/// Process-wide dispatch profiling (ISSUE 9): lock-free counters the
/// observability plane snapshots into pool-occupancy gauges. Updates are
/// one `Relaxed` fetch-add per dispatch — nothing per task — so the
/// accounting never perturbs the kernels it measures. Busy time covers
/// the parallel region of pool and scoped dispatches (post → barrier);
/// inline runs are counted but not timed (they are the latency-critical
/// batch-of-1 path, and their cost is the kernel itself).
pub mod profile {
    use std::sync::atomic::{AtomicU64, Ordering};

    pub(super) static POOL_DISPATCHES: AtomicU64 = AtomicU64::new(0);
    pub(super) static INLINE_RUNS: AtomicU64 = AtomicU64::new(0);
    pub(super) static SCOPED_FALLBACKS: AtomicU64 = AtomicU64::new(0);
    pub(super) static TASKS: AtomicU64 = AtomicU64::new(0);
    pub(super) static BUSY_US: AtomicU64 = AtomicU64::new(0);

    /// One snapshot of the pool's lifetime dispatch ledger.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct PoolStats {
        /// Dispatches that fanned out over the persistent workers.
        pub pool_dispatches: u64,
        /// Dispatches that ran inline on the caller (sub-grain work,
        /// single task, capped thread, or reentrant).
        pub inline_runs: u64,
        /// Dispatches that found the job slot busy and fell back to
        /// scoped threads (concurrent-dispatcher contention).
        pub scoped_fallbacks: u64,
        /// Total tasks across all dispatches.
        pub tasks: u64,
        /// Wall-µs spent inside parallel regions (pool + scoped), i.e.
        /// post-to-barrier; the idle share of a serving window is
        /// `window_us - busy_us`.
        pub busy_us: u64,
    }

    pub fn stats() -> PoolStats {
        PoolStats {
            pool_dispatches: POOL_DISPATCHES.load(Ordering::Relaxed),
            inline_runs: INLINE_RUNS.load(Ordering::Relaxed),
            scoped_fallbacks: SCOPED_FALLBACKS.load(Ordering::Relaxed),
            tasks: TASKS.load(Ordering::Relaxed),
            busy_us: BUSY_US.load(Ordering::Relaxed),
        }
    }
}

/// The job closure, lifetime-erased. Soundness: see module docs.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (calling it from several threads is fine)
// and the dispatch barrier guarantees it outlives every access.
unsafe impl Send for JobPtr {}

struct JobSlot {
    /// A dispatch is in flight (serializes concurrent dispatchers).
    busy: bool,
    /// Bumped once per dispatch; workers only run tasks of the generation
    /// they observed when they woke.
    generation: u64,
    job: Option<JobPtr>,
    n_tasks: usize,
    /// Shared claim cursor: next unclaimed task index.
    next_task: usize,
    /// Unfinished tasks of the current generation.
    remaining: usize,
    /// A task of the current generation panicked; the dispatcher re-raises
    /// after the barrier (mirroring `std::thread::scope` semantics) so a
    /// panicking kernel cannot wedge the process-wide pool.
    panicked: bool,
}

struct Shared {
    slot: Mutex<JobSlot>,
    /// Workers wait here for a new generation.
    job_cv: Condvar,
    /// The dispatcher waits here for `remaining == 0`.
    done_cv: Condvar,
}

struct Pool {
    shared: Arc<Shared>,
    /// Spawned worker threads (the dispatcher is the +1th worker).
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// True while this thread is executing a pool task — a nested dispatch
    /// from inside a kernel would deadlock on `busy`, so it runs inline.
    static IN_TASK: Cell<bool> = const { Cell::new(false) };
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let threads = num_threads();
        let workers = threads.saturating_sub(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(JobSlot {
                busy: false,
                generation: 0,
                job: None,
                n_tasks: 0,
                next_task: 0,
                remaining: 0,
                panicked: false,
            }),
            job_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            // detached: workers park forever and die with the process
            let _ = std::thread::Builder::new()
                .name(format!("dynadiag-pool-{}", i))
                .spawn(move || worker_loop(sh));
        }
        crate::info!(
            "kernel pool: {} threads ({} persistent workers + caller){}",
            threads,
            workers,
            if std::env::var("DYNADIAG_THREADS").is_ok() {
                " [DYNADIAG_THREADS override]"
            } else {
                ""
            }
        );
        Pool { shared, workers }
    })
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    let mut guard = shared.slot.lock().unwrap();
    loop {
        while guard.generation == seen || guard.job.is_none() {
            guard = shared.job_cv.wait(guard).unwrap();
        }
        seen = guard.generation;
        let job = guard.job.expect("job present at wake");
        while guard.next_task < guard.n_tasks {
            let t = guard.next_task;
            guard.next_task += 1;
            drop(guard);
            IN_TASK.with(|f| f.set(true));
            // SAFETY: the dispatcher blocks until `remaining == 0`, so the
            // closure (and everything it borrows) is alive for this call.
            // catch_unwind keeps a panicking task from leaving `remaining`
            // stuck (which would deadlock every future dispatch).
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                (&*job.0)(t)
            }));
            IN_TASK.with(|f| f.set(false));
            guard = shared.slot.lock().unwrap();
            if result.is_err() {
                guard.panicked = true;
            }
            guard.remaining -= 1;
            if guard.remaining == 0 {
                shared.done_cv.notify_all();
            }
            if guard.generation != seen {
                // a new dispatch was posted the instant ours drained;
                // fall through to the outer loop to pick it up fresh
                break;
            }
        }
    }
}

/// Run `job(0..n_tasks)` across the pool, blocking until every task has
/// completed. Tasks may run on any pool thread or on the caller; the claim
/// cursor balances uneven task costs. Reentrant calls (from inside a task)
/// and `n_tasks <= 1` run inline.
pub fn parallel_tasks<F>(n_tasks: usize, job: F)
where
    F: Fn(usize) + Sync,
{
    dispatch(n_tasks, &job);
}

fn dispatch(n_tasks: usize, job: &(dyn Fn(usize) + Sync)) {
    use std::sync::atomic::Ordering;
    if n_tasks == 0 {
        return;
    }
    let p = pool();
    if p.workers == 0 || n_tasks == 1 || effective_threads() == 1 || IN_TASK.with(|f| f.get()) {
        profile::INLINE_RUNS.fetch_add(1, Ordering::Relaxed);
        profile::TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
        for t in 0..n_tasks {
            job(t);
        }
        return;
    }
    // ddlint: allow(clock) -- pool profiling counter, not request latency
    let t0 = std::time::Instant::now();
    // Lifetime-erase the job for the persistent workers.
    // SAFETY: this function does not return until `remaining == 0` (the
    // barrier below), so the erased borrow never outlives the data it
    // points into.
    let job_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(job) };
    let ptr = JobPtr(job_static as *const (dyn Fn(usize) + Sync));

    let shared = &p.shared;
    let mut guard = shared.slot.lock().unwrap();
    if guard.busy {
        // another dispatch already owns the job slot: fall back to scoped
        // threads for this one job so concurrent dispatchers keep their
        // parallelism (idling until the slot frees would serialize them;
        // running purely inline would cost this caller its speedup)
        drop(guard);
        profile::SCOPED_FALLBACKS.fetch_add(1, Ordering::Relaxed);
        profile::TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
        run_scoped(n_tasks, job);
        profile::BUSY_US.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        return;
    }
    profile::POOL_DISPATCHES.fetch_add(1, Ordering::Relaxed);
    profile::TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
    guard.busy = true;
    guard.generation = guard.generation.wrapping_add(1);
    guard.job = Some(ptr);
    guard.n_tasks = n_tasks;
    guard.next_task = 0;
    guard.remaining = n_tasks;
    drop(guard);
    shared.job_cv.notify_all();

    // the dispatcher participates in its own job
    loop {
        let mut guard = shared.slot.lock().unwrap();
        if guard.next_task >= guard.n_tasks {
            while guard.remaining > 0 {
                guard = shared.done_cv.wait(guard).unwrap();
            }
            let panicked = guard.panicked;
            guard.panicked = false;
            guard.job = None;
            guard.busy = false;
            drop(guard);
            profile::BUSY_US.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
            if panicked {
                // re-raise only after the barrier, so every borrow the
                // erased job held is already dead (scope-like semantics)
                panic!("a kernel pool task panicked");
            }
            return;
        }
        let t = guard.next_task;
        guard.next_task += 1;
        drop(guard);
        // mark the dispatcher as in-task too, so a nested dispatch from
        // inside this job runs inline instead of waiting on our own `busy`;
        // catch panics so the pool bookkeeping always completes
        IN_TASK.with(|f| f.set(true));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(t)));
        IN_TASK.with(|f| f.set(false));
        let mut guard = shared.slot.lock().unwrap();
        if result.is_err() {
            guard.panicked = true;
        }
        guard.remaining -= 1;
        if guard.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Contended-dispatch fallback: run one job on freshly scoped threads
/// pulling tasks from a shared cursor. Pays the seed implementation's
/// spawn cost, but only when the persistent pool's job slot is occupied
/// by another dispatcher. Panics propagate through `scope` as before.
fn run_scoped(n_tasks: usize, job: &(dyn Fn(usize) + Sync)) {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let next = AtomicUsize::new(0);
    let helpers = effective_threads().min(n_tasks).saturating_sub(1);
    let run_tasks = || {
        IN_TASK.with(|f| f.set(true));
        loop {
            let t = next.fetch_add(1, Ordering::Relaxed);
            if t >= n_tasks {
                break;
            }
            job(t);
        }
        IN_TASK.with(|f| f.set(false));
    };
    std::thread::scope(|scope| {
        for _ in 0..helpers {
            scope.spawn(run_tasks);
        }
        run_tasks();
    });
}

/// Upper bound on chunks per dispatch (stack-allocated chunk table).
const MAX_TASKS: usize = 64;

/// Partition `data` (logically `rows × row_len`) into contiguous row chunks
/// and run `f(first_row, chunk)` on each, in parallel when the flop count
/// justifies waking workers. `flops_per_row` is the caller's estimate of
/// arithmetic per row (e.g. `2 * n_in * n_out` for a GEMM output row); the
/// grain heuristic sizes chunks so each parallel task covers at least
/// [`TASK_GRAIN_FLOPS`], and runs everything inline below that.
pub fn parallel_rows<T, F>(data: &mut [T], row_len: usize, flops_per_row: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    if row_len == 0 || rows * row_len != data.len() {
        // not row-shaped: run inline rather than guess a partition
        f(0, data);
        return;
    }
    let total_flops = rows.saturating_mul(flops_per_row.max(1));
    let n_tasks = effective_threads()
        .min(total_flops / TASK_GRAIN_FLOPS)
        .min(rows)
        .min(MAX_TASKS)
        .max(1);
    if n_tasks <= 1 {
        f(0, data);
        return;
    }
    let chunk_rows = rows.div_ceil(n_tasks);
    // chunk table on the stack: no allocation on the dispatch path
    let mut chunks: [Mutex<Option<(usize, &mut [T])>>; MAX_TASKS] =
        std::array::from_fn(|_| Mutex::new(None));
    let mut n_chunks = 0usize;
    {
        let mut rest = data;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take = chunk_rows.min(rows - row0) * row_len;
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            *chunks[n_chunks].get_mut().unwrap() = Some((row0, head));
            n_chunks += 1;
            row0 += take / row_len;
        }
    }
    dispatch(n_chunks, &|t: usize| {
        let (first, chunk) = chunks[t]
            .lock()
            .unwrap()
            .take()
            .expect("each chunk claimed exactly once");
        f(first, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_row_exactly_once() {
        let rows = 37;
        let row_len = 5;
        let mut data = vec![0u32; rows * row_len];
        // huge flop estimate to force the parallel path
        parallel_rows(&mut data, row_len, 1 << 20, |first, chunk| {
            for (r, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v += (first + r) as u32 + 1;
                }
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, (i / row_len) as u32 + 1, "row {}", i / row_len);
        }
    }

    #[test]
    fn small_work_runs_inline() {
        let mut data = vec![0u8; 6];
        parallel_rows(&mut data, 3, 10, |first, chunk| {
            assert_eq!(first, 0);
            assert_eq!(chunk.len(), 6);
            chunk.fill(9);
        });
        assert!(data.iter().all(|&v| v == 9));
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let mut empty: Vec<f32> = Vec::new();
        parallel_rows(&mut empty, 4, 1, |_, _| {});
        let mut flat = vec![1.0f32; 8];
        parallel_rows(&mut flat, 0, 1, |_, chunk| chunk.fill(2.0));
        assert!(flat.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn parallel_tasks_runs_each_task_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
        parallel_tasks(hits.len(), |t| {
            hits[t].fetch_add(1, Ordering::SeqCst);
        });
        for (t, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {}", t);
        }
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let inner_hits = AtomicUsize::new(0);
        parallel_tasks(4, |_| {
            // reentrant dispatch from inside a task must not deadlock
            parallel_tasks(3, |_| {
                inner_hits.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(inner_hits.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let caught = std::panic::catch_unwind(|| {
            parallel_tasks(4, |t| {
                if t == 2 {
                    panic!("boom");
                }
            });
        });
        assert!(caught.is_err(), "task panic must reach the dispatcher");
        // the pool must keep dispatching normally afterwards
        let mut data = vec![0u8; 32];
        parallel_rows(&mut data, 4, 1 << 20, |_, chunk| chunk.fill(1));
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn local_thread_cap_of_one_runs_inline() {
        // a capped thread must execute every task itself — the shard-aware
        // accounting that keeps N shards from oversubscribing the pool
        std::thread::spawn(|| {
            set_local_thread_cap(1);
            assert_eq!(effective_threads(), 1);
            let caller = std::thread::current().id();
            let mut data = vec![0u32; 64];
            parallel_rows(&mut data, 4, 1 << 20, |_, chunk| {
                assert_eq!(std::thread::current().id(), caller, "must run inline");
                chunk.fill(1);
            });
            assert!(data.iter().all(|&v| v == 1));
            set_local_thread_cap(0);
            assert_eq!(effective_threads(), num_threads());
        })
        .join()
        .unwrap();
    }

    #[test]
    fn local_thread_cap_is_per_thread() {
        std::thread::spawn(|| {
            set_local_thread_cap(1);
            // a sibling thread is unaffected by this thread's cap
            std::thread::spawn(|| {
                assert_eq!(effective_threads(), num_threads());
            })
            .join()
            .unwrap();
        })
        .join()
        .unwrap();
    }

    #[test]
    fn profile_counters_advance_monotonically() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let before = profile::stats();
        // single task → the inline dispatch branch
        parallel_tasks(1, |_| {});
        // multi-task over real work → pool (or scoped, under test
        // concurrency) path; either way tasks + busy accounting move
        let hits = AtomicUsize::new(0);
        parallel_tasks(8, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        let after = profile::stats();
        assert_eq!(hits.load(Ordering::SeqCst), 8);
        assert!(after.inline_runs > before.inline_runs);
        assert!(after.tasks >= before.tasks + 9);
        if num_threads() > 1 {
            assert!(
                after.pool_dispatches + after.scoped_fallbacks
                    > before.pool_dispatches + before.scoped_fallbacks
            );
            assert!(after.busy_us > before.busy_us);
        }
    }

    #[test]
    fn generations_stay_isolated_across_many_dispatches() {
        for round in 0..200usize {
            let rows = 1 + (round * 7) % 19;
            let row_len = 1 + round % 5;
            let mut data = vec![0u64; rows * row_len];
            parallel_rows(&mut data, row_len, 1 << 20, |first, chunk| {
                for (r, row) in chunk.chunks_exact_mut(row_len).enumerate() {
                    row.fill((first + r) as u64);
                }
            });
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, (i / row_len) as u64, "round {} elem {}", round, i);
            }
        }
    }
}
