//! SIMD microkernels and one-time runtime ISA dispatch for the diag hot
//! loops (ROADMAP item 1).
//!
//! Every diagonal product in [`super::diag`] decomposes into contiguous
//! wrap segments whose inner loop is one element-wise fused multiply-add
//! over three equal-length slices: `acc[i] += a[i] * b[i]`. That primitive
//! — [`Microkernel::fma3`] — is the whole ISA surface, so each vector path
//! is a few dozen lines and the op-level code is written **once**,
//! generically, in `diag.rs`.
//!
//! Three paths ship:
//!
//! * **scalar** — `f32::mul_add` per element. Always available; this is the
//!   parity **oracle** every other path is fuzzed against.
//! * **avx2** — `x86_64` AVX2 + FMA, 8-wide `_mm256_fmadd_ps` with a 4×8
//!   register-blocked main loop (four independent load/FMA/store pipelines
//!   per iteration, the way `dense.rs` register-blocks its GEMM).
//! * **neon** — `aarch64` NEON, 4-wide `vfmaq_f32` with a 4×4
//!   register-blocked main loop.
//!
//! **Bit-identity contract.** Each element is computed with a *single*
//! rounding: hardware fused multiply-add on the vector paths, and
//! `f32::mul_add` (IEEE-correct fused) on the scalar path and on every
//! vector remainder tail. Because `fma3` is purely element-wise — no
//! cross-lane reduction anywhere — every path produces **bit-identical**
//! output for every input, which `tests/kernel_parity.rs` (seeded fuzz vs
//! the scalar oracle) and `tests/golden_diag_microkernel.rs` (committed bit
//! patterns) enforce. The one deliberate cost: on hosts whose *compiled*
//! baseline lacks hardware FMA (generic `x86-64` without AVX2 at runtime),
//! the scalar path pays a libm `fmaf` call per element — correctness-first;
//! the dispatched vector path is what production traffic runs.
//!
//! **Dispatch** happens once per process ([`active`], a `OnceLock`):
//! `DYNADIAG_ISA=scalar|avx2|neon|auto` (default `auto` = widest detected
//! path). Forcing an ISA the host cannot execute falls back to scalar with
//! a logged warning instead of an illegal-instruction crash, so one CI
//! command line works on every runner in the cross-ISA matrix. Per-ISA
//! entry points (`diag::spmm_t_on` etc.) take an explicit [`Isa`] so tests
//! and benches exercise every available lane width in a single process,
//! without env juggling.

use std::sync::OnceLock;

/// A dispatched instruction-set path for the diag microkernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// `f32::mul_add` per element — always available, the parity oracle.
    Scalar,
    /// x86-64 AVX2 + FMA, 8 f32 lanes.
    Avx2,
    /// aarch64 NEON, 4 f32 lanes.
    Neon,
}

impl Isa {
    /// The `DYNADIAG_ISA` spelling of this path.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// f32 lanes per vector register on this path (1 for scalar).
    pub fn lanes(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 8,
            Isa::Neon => 4,
        }
    }

    /// Can the current build *and* host actually execute this path?
    pub fn detected(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            // NEON is architecturally mandatory on aarch64
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            _ => false,
        }
    }
}

/// ISA paths this host can execute, scalar (the oracle) always first and
/// the widest path last. The parity harness iterates this.
pub fn available() -> &'static [Isa] {
    static AVAIL: OnceLock<Vec<Isa>> = OnceLock::new();
    AVAIL.get_or_init(|| {
        let mut v = vec![Isa::Scalar];
        for isa in [Isa::Neon, Isa::Avx2] {
            if isa.detected() {
                v.push(isa);
            }
        }
        v
    })
}

/// The dispatched ISA, resolved exactly once per process from
/// `DYNADIAG_ISA` (`scalar|avx2|neon|auto`; unset = `auto` = widest
/// detected path). A forced ISA the host cannot execute degrades to
/// scalar with a logged warning — never to a crash — so a cross-ISA CI
/// matrix can run identical commands on every runner.
///
/// Resolution allocates (env read, the `available` vec); callers that gate
/// on zero-allocation steady-state windows should touch this once before
/// opening the measured window (`tests/native_steady_state.rs` does).
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let widest = *available().last().expect("scalar is always available");
        let req = std::env::var("DYNADIAG_ISA").unwrap_or_default();
        let isa = match req.to_ascii_lowercase().as_str() {
            "" | "auto" => widest,
            "scalar" => Isa::Scalar,
            "avx2" => Isa::Avx2,
            "neon" => Isa::Neon,
            other => {
                crate::info!(
                    "DYNADIAG_ISA='{}' unrecognized (want scalar|avx2|neon|auto); using auto",
                    other
                );
                widest
            }
        };
        let isa = if isa.detected() {
            isa
        } else {
            crate::info!(
                "DYNADIAG_ISA={} is not executable on this host; falling back to scalar",
                isa.name()
            );
            Isa::Scalar
        };
        crate::info!(
            "diag microkernels: {} ({} f32 lane{})",
            isa.name(),
            isa.lanes(),
            if isa.lanes() == 1 { "" } else { "s" }
        );
        isa
    })
}

/// Clamp an explicitly requested ISA to something this host can execute
/// (same degradation contract as `DYNADIAG_ISA` forcing). The per-ISA op
/// entry points route through this so `spmm_t_on(Isa::Avx2, ..)` on a
/// non-AVX2 host runs the scalar path instead of faulting.
pub(crate) fn sanitize(isa: Isa) -> Isa {
    if isa.detected() {
        isa
    } else {
        Isa::Scalar
    }
}

/// One ISA flavor of the element-wise fused-multiply-add primitive that
/// every diag hot loop decomposes into.
///
/// Contract (what the cross-ISA bit-identity rests on): for equal-length
/// slices, `acc[i] <- round(a[i] * b[i] + acc[i])` with a **single**
/// rounding per element, elements independent (no cross-lane arithmetic).
pub(crate) trait Microkernel {
    /// `acc[i] += a[i] * b[i]`, fused, over `acc.len()` elements.
    /// All three slices must have equal length.
    fn fma3(acc: &mut [f32], a: &[f32], b: &[f32]);
}

/// Portable scalar path — `f32::mul_add` per element. The parity oracle.
pub(crate) struct ScalarKernel;

impl Microkernel for ScalarKernel {
    #[inline]
    fn fma3(acc: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert!(acc.len() == a.len() && acc.len() == b.len());
        for ((y, &av), &bv) in acc.iter_mut().zip(a).zip(b) {
            *y = av.mul_add(bv, *y);
        }
    }
}

/// AVX2 + FMA path: 8 f32 lanes, 4×8 register-blocked main loop.
#[cfg(target_arch = "x86_64")]
pub(crate) struct Avx2Kernel;

#[cfg(target_arch = "x86_64")]
impl Microkernel for Avx2Kernel {
    #[inline]
    fn fma3(acc: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert!(acc.len() == a.len() && acc.len() == b.len());
        // SAFETY: this type is only selected by `diag`'s dispatch after
        // `Isa::Avx2.detected()` returned true (see `sanitize`/`active`).
        unsafe { fma3_avx2(acc, a, b) }
    }
}

// SAFETY: `unsafe` solely for `#[target_feature]` — callers must prove
// AVX2+FMA are present (the dispatch layer's `detected()` check). All
// pointer offsets stay below `n = acc.len()`, which equals `a.len()` and
// `b.len()` by the caller's contract (debug-asserted at the call site),
// and `loadu`/`storeu` carry no alignment requirement.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn fma3_avx2(acc: &mut [f32], a: &[f32], b: &[f32]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let (ap, bp, yp) = (a.as_ptr(), b.as_ptr(), acc.as_mut_ptr());
    let mut i = 0usize;
    // 4 × 8-lane register block: four independent load/FMA/store pipelines
    // per iteration keep the FMA units fed
    while i + 32 <= n {
        let y0 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i)),
            _mm256_loadu_ps(bp.add(i)),
            _mm256_loadu_ps(yp.add(i)),
        );
        let y1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 8)),
            _mm256_loadu_ps(bp.add(i + 8)),
            _mm256_loadu_ps(yp.add(i + 8)),
        );
        let y2 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 16)),
            _mm256_loadu_ps(bp.add(i + 16)),
            _mm256_loadu_ps(yp.add(i + 16)),
        );
        let y3 = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i + 24)),
            _mm256_loadu_ps(bp.add(i + 24)),
            _mm256_loadu_ps(yp.add(i + 24)),
        );
        _mm256_storeu_ps(yp.add(i), y0);
        _mm256_storeu_ps(yp.add(i + 8), y1);
        _mm256_storeu_ps(yp.add(i + 16), y2);
        _mm256_storeu_ps(yp.add(i + 24), y3);
        i += 32;
    }
    while i + 8 <= n {
        let y = _mm256_fmadd_ps(
            _mm256_loadu_ps(ap.add(i)),
            _mm256_loadu_ps(bp.add(i)),
            _mm256_loadu_ps(yp.add(i)),
        );
        _mm256_storeu_ps(yp.add(i), y);
        i += 8;
    }
    // remainder tail: `mul_add` is fused too, so the tail lanes round
    // exactly like the vector lanes (bit-identity across segment splits)
    while i < n {
        *yp.add(i) = (*ap.add(i)).mul_add(*bp.add(i), *yp.add(i));
        i += 1;
    }
}

/// NEON path: 4 f32 lanes, 4×4 register-blocked main loop.
#[cfg(target_arch = "aarch64")]
pub(crate) struct NeonKernel;

#[cfg(target_arch = "aarch64")]
impl Microkernel for NeonKernel {
    #[inline]
    fn fma3(acc: &mut [f32], a: &[f32], b: &[f32]) {
        debug_assert!(acc.len() == a.len() && acc.len() == b.len());
        // SAFETY: NEON is baseline on aarch64; this type only exists there.
        unsafe { fma3_neon(acc, a, b) }
    }
}

// SAFETY: `unsafe` solely for `#[target_feature]` — NEON is baseline on
// aarch64, so the feature is always present. All pointer offsets stay
// below `n = acc.len()`, which equals `a.len()` and `b.len()` by the
// caller's contract (debug-asserted at the call site); `vld1q`/`vst1q`
// tolerate unaligned f32 pointers.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn fma3_neon(acc: &mut [f32], a: &[f32], b: &[f32]) {
    use std::arch::aarch64::*;
    let n = acc.len();
    let (ap, bp, yp) = (a.as_ptr(), b.as_ptr(), acc.as_mut_ptr());
    let mut i = 0usize;
    // 4 × 4-lane register block (vfmaq is a fused a + b*c)
    while i + 16 <= n {
        let y0 = vfmaq_f32(vld1q_f32(yp.add(i)), vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        let y1 = vfmaq_f32(
            vld1q_f32(yp.add(i + 4)),
            vld1q_f32(ap.add(i + 4)),
            vld1q_f32(bp.add(i + 4)),
        );
        let y2 = vfmaq_f32(
            vld1q_f32(yp.add(i + 8)),
            vld1q_f32(ap.add(i + 8)),
            vld1q_f32(bp.add(i + 8)),
        );
        let y3 = vfmaq_f32(
            vld1q_f32(yp.add(i + 12)),
            vld1q_f32(ap.add(i + 12)),
            vld1q_f32(bp.add(i + 12)),
        );
        vst1q_f32(yp.add(i), y0);
        vst1q_f32(yp.add(i + 4), y1);
        vst1q_f32(yp.add(i + 8), y2);
        vst1q_f32(yp.add(i + 12), y3);
        i += 16;
    }
    while i + 4 <= n {
        let y = vfmaq_f32(vld1q_f32(yp.add(i)), vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i)));
        vst1q_f32(yp.add(i), y);
        i += 4;
    }
    // fused scalar tail — rounds exactly like the vector lanes
    while i < n {
        *yp.add(i) = (*ap.add(i)).mul_add(*bp.add(i), *yp.add(i));
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive one `fma3` call per available ISA on identical buffers and
    /// require bitwise-equal results at every length that exercises the
    /// 4×lane main loop, the 1×lane loop, and the scalar tail.
    #[test]
    fn fma3_bitwise_parity_across_isas_at_all_remainders() {
        let mut state = 0x2545F4914F6CDD1Du64;
        let mut next = move || {
            // xorshift into (-2, 2): plenty of rounding-sensitive mantissas
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state >> 40) as f32 / (1u64 << 22) as f32) - 2.0
        };
        for n in (0usize..=67).chain([128, 129, 255]) {
            let a: Vec<f32> = (0..n).map(|_| next()).collect();
            let b: Vec<f32> = (0..n).map(|_| next()).collect();
            let acc0: Vec<f32> = (0..n).map(|_| next()).collect();
            let mut want = acc0.clone();
            ScalarKernel::fma3(&mut want, &a, &b);
            for &isa in available() {
                let mut got = acc0.clone();
                match isa {
                    Isa::Scalar => ScalarKernel::fma3(&mut got, &a, &b),
                    #[cfg(target_arch = "x86_64")]
                    Isa::Avx2 => Avx2Kernel::fma3(&mut got, &a, &b),
                    #[cfg(target_arch = "aarch64")]
                    Isa::Neon => NeonKernel::fma3(&mut got, &a, &b),
                    _ => unreachable!("available() only lists executable ISAs"),
                }
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "{} diverges from scalar at n={} i={} ({} vs {})",
                        isa.name(),
                        n,
                        i,
                        g,
                        w
                    );
                }
            }
        }
    }

    #[test]
    fn available_starts_with_the_scalar_oracle() {
        let avail = available();
        assert_eq!(avail[0], Isa::Scalar);
        assert!(avail.iter().all(|i| i.detected()));
        // widest-last ordering: lanes are non-decreasing
        for w in avail.windows(2) {
            assert!(w[0].lanes() <= w[1].lanes());
        }
    }

    #[test]
    fn active_is_executable_and_stable() {
        let a = active();
        assert!(a.detected(), "dispatched ISA must be executable");
        assert_eq!(a, active(), "dispatch resolves once");
        assert!(available().contains(&a));
    }

    #[test]
    fn sanitize_never_returns_an_unexecutable_isa() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert!(sanitize(isa).detected());
        }
    }

    #[test]
    fn names_round_trip_the_env_spellings() {
        assert_eq!(Isa::Scalar.name(), "scalar");
        assert_eq!(Isa::Avx2.name(), "avx2");
        assert_eq!(Isa::Neon.name(), "neon");
        assert_eq!(Isa::Scalar.lanes(), 1);
        assert_eq!(Isa::Avx2.lanes(), 8);
        assert_eq!(Isa::Neon.lanes(), 4);
    }
}
