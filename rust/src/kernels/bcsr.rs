//! Blocked-CSR SpMM — the SmaT-style execution format DynaDiag converts
//! finalized diagonals into (Sec 3.3 / Apdx D).
//!
//! Same math as [`crate::bcsr::Bcsr::matmul_t`], restructured for the native
//! backend: parallel over batch rows, with the `bs × bs` block micro-kernel
//! accumulating into a register before touching `y`.

use super::pool::parallel_rows;

/// `y[b, rows] = x[b, cols] @ Wᵀ` where W is `[rows, cols]` in BCSR with
/// square `bs`-blocks (`row_ptr: [rows/bs + 1]`, `col_idx: [nnzb]`,
/// `blocks: [nnzb * bs * bs]` row-major within a block). `y` is overwritten.
#[allow(clippy::too_many_arguments)]
pub fn spmm_t(
    x: &[f32],
    row_ptr: &[usize],
    col_idx: &[usize],
    blocks: &[f32],
    bs: usize,
    rows: usize,
    cols: usize,
    y: &mut [f32],
    b: usize,
) {
    assert!(bs > 0 && rows % bs == 0 && cols % bs == 0, "bcsr spmm_t: bad block size");
    let nbr = rows / bs;
    assert_eq!(row_ptr.len(), nbr + 1, "bcsr spmm_t: row_ptr length");
    assert_eq!(x.len(), b * cols, "bcsr spmm_t: x length");
    assert_eq!(y.len(), b * rows, "bcsr spmm_t: y length");
    assert_eq!(blocks.len(), col_idx.len() * bs * bs, "bcsr spmm_t: blocks length");
    y.fill(0.0);
    // each batch row touches every stored block once
    parallel_rows(y, rows, 2 * col_idx.len() * bs * bs, |first_row, y_chunk| {
        let batch_rows = y_chunk.len() / rows;
        for r in 0..batch_rows {
            let xr = &x[(first_row + r) * cols..(first_row + r + 1) * cols];
            let yr = &mut y_chunk[r * rows..(r + 1) * rows];
            for br in 0..nbr {
                for p in row_ptr[br]..row_ptr[br + 1] {
                    let bc = col_idx[p];
                    debug_assert!(bc * bs + bs <= cols, "block col out of range");
                    let blk = &blocks[p * bs * bs..(p + 1) * bs * bs];
                    let xp = &xr[bc * bs..bc * bs + bs];
                    let yp = &mut yr[br * bs..br * bs + bs];
                    for i in 0..bs {
                        let brow = &blk[i * bs..(i + 1) * bs];
                        let mut acc = 0.0f32;
                        for j in 0..bs {
                            acc += brow[j] * xp[j];
                        }
                        yp[i] += acc;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use crate::bcsr::Bcsr;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn matches_bcsr_reference() {
        let mut rng = Rng::new(61);
        for &(rows, cols, bs, b) in &[(8usize, 8usize, 2usize, 3usize), (24, 16, 4, 5)] {
            let mut w = Tensor::zeros(&[rows, cols]);
            for v in w.data.iter_mut() {
                if rng.bool(0.25) {
                    *v = rng.normal_f32(0.0, 1.0);
                }
            }
            let bc = Bcsr::from_dense(&w, bs).unwrap();
            let x = Tensor::randn(&[b, cols], 1.0, &mut rng);
            let mut y = vec![0.0f32; b * rows];
            super::spmm_t(
                &x.data, &bc.row_ptr, &bc.col_idx, &bc.blocks, bs, rows, cols, &mut y, b,
            );
            let want = bc.matmul_t(&x).unwrap();
            let diff = want.data.iter().zip(&y).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
            assert!(diff < 1e-4, "rows={} cols={} bs={}: diff {}", rows, cols, bs, diff);
        }
    }
}
