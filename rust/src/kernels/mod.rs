//! Native CPU kernel subsystem — the measured compute path behind the
//! [`crate::runtime`] `NativeBackend` and the `cargo bench --bench kernels`
//! sweep.
//!
//! Three families, all verified against the `tensor::Tensor` /
//! `sparsity::diagonal::DiagMatrix` / `bcsr::Bcsr` reference math by unit
//! tests here and the property tests in `tests/kernel_parity.rs`:
//!
//! * [`dense`] — cache-blocked GEMM with 8-way output register blocking
//!   (`y = x @ Wᵀ`, plus the two backward products) — the baseline Fig 7
//!   divides by,
//! * [`diag`] — offset-major diagonal SpMM with branch-free two-segment
//!   inner loops, forward and both backward products (the paper's custom
//!   kernel, Sec 3.3), executed through the dispatched SIMD microkernels,
//! * [`bcsr`] — blocked-CSR SpMM (the SmaT-style converted format).
//!
//! The diag inner loops run on [`microkernel`], an explicit SIMD layer
//! with one-time runtime ISA dispatch — AVX2/FMA 8-wide, NEON 4-wide, or
//! a scalar `mul_add` oracle, overridable via
//! `DYNADIAG_ISA=scalar|avx2|neon|auto`. All paths are **bit-identical**
//! per element (single-rounding fused multiply-add everywhere), enforced
//! by the cross-ISA fuzz harness in `tests/kernel_parity.rs` and the
//! committed bit patterns in `tests/golden_diag_microkernel.rs`.
//!
//! Parallelism comes from [`pool`], a dependency-free **persistent worker
//! pool** (long-lived threads, condvar dispatch, generation-counted
//! barriers) with a flop-based inline/parallel grain; set
//! `DYNADIAG_THREADS=1` for fully deterministic single-core runs. Results
//! are deterministic at any fixed thread count *and* any dispatched ISA;
//! across thread counts only [`diag::grad_values`]'s batch-split reduction
//! can differ in the last float bits (its partial-sum width follows the
//! worker count — not the lane width, which never changes results).

pub mod bcsr;
pub mod dense;
pub mod diag;
pub mod microkernel;
pub mod pool;

use anyhow::{bail, Result};

use crate::sparsity::diagonal::DiagMatrix;
use crate::tensor::Tensor;

const SQRT_2_OVER_PI: f32 = 0.797_884_56;
const GELU_C: f32 = 0.044_715;

/// Tanh-approximation GELU (the L2 zoo's activation). This is the single
/// canonical definition: the native step functions and the fused serving
/// kernel ([`diag::spmm_t_bias`]) both call it, so training-time forward,
/// batched serving, and batch-of-1 serving compute bit-identical
/// activations.
#[inline]
pub fn gelu(z: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (z + GELU_C * z * z * z);
    0.5 * z * (1.0 + u.tanh())
}

/// Derivative of [`gelu`] — kept beside it so the activation and its
/// gradient always share one set of constants.
#[inline]
pub fn gelu_prime(z: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (z + GELU_C * z * z * z);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * z * z)
}

/// A diagonal matrix packed for the native kernels: offsets + one flat
/// offset-major value buffer (`values[j * n_out + i]`), the exact layout the
/// L1 Pallas kernel consumes (`micro_diag_*` artifact inputs).
#[derive(Clone, Debug)]
pub struct DiagPacked {
    pub n_out: usize,
    pub n_in: usize,
    pub offsets: Vec<usize>,
    pub values: Vec<f32>,
}

impl DiagPacked {
    pub fn from_matrix(d: &DiagMatrix) -> DiagPacked {
        let mut values = Vec::with_capacity(d.k() * d.n_out);
        for v in &d.values {
            values.extend_from_slice(v);
        }
        DiagPacked {
            n_out: d.n_out,
            n_in: d.n_in,
            offsets: d.offsets.clone(),
            values,
        }
    }

    pub fn k(&self) -> usize {
        self.offsets.len()
    }

    /// Forward `y = x @ Wᵀ` through the native kernel.
    pub fn matmul_t(&self, x: &Tensor) -> Result<Tensor> {
        if x.rank() != 2 || x.cols() != self.n_in {
            bail!("DiagPacked matmul_t: x {:?} vs n_in {}", x.shape, self.n_in);
        }
        let b = x.rows();
        let mut y = Tensor::zeros(&[b, self.n_out]);
        diag::spmm_t(&x.data, &self.offsets, &self.values, &mut y.data, b, self.n_in, self.n_out);
        Ok(y)
    }

    /// Transposed product `dx = dy @ W` through the native kernel.
    pub fn matmul(&self, dy: &Tensor) -> Result<Tensor> {
        if dy.rank() != 2 || dy.cols() != self.n_out {
            bail!("DiagPacked matmul: dy {:?} vs n_out {}", dy.shape, self.n_out);
        }
        let b = dy.rows();
        let mut dx = Tensor::zeros(&[b, self.n_in]);
        diag::spmm(&dy.data, &self.offsets, &self.values, &mut dx.data, b, self.n_in, self.n_out);
        Ok(dx)
    }
}

/// Dense `y = x @ Wᵀ` through the native kernel (Tensor-level wrapper).
pub fn dense_matmul_t(w: &Tensor, x: &Tensor) -> Result<Tensor> {
    if w.rank() != 2 || x.rank() != 2 || x.cols() != w.cols() {
        bail!("dense_matmul_t: shapes {:?} x {:?}", x.shape, w.shape);
    }
    let (b, n_in, n_out) = (x.rows(), w.cols(), w.rows());
    let mut y = Tensor::zeros(&[b, n_out]);
    dense::gemm_t(&x.data, &w.data, &mut y.data, b, n_in, n_out);
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn packed_wrappers_match_reference() {
        let mut rng = Rng::new(71);
        let (b, n_in, n_out, k) = (4usize, 16usize, 32usize, 5usize);
        let offsets = rng.choose_k(n_in, k);
        let mut d = DiagMatrix::new(n_out, n_in, offsets);
        for j in 0..d.k() {
            for i in 0..n_out {
                d.values[j][i] = rng.normal_f32(0.0, 1.0);
            }
        }
        let p = DiagPacked::from_matrix(&d);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        assert!(p.matmul_t(&x).unwrap().max_abs_diff(&d.matmul_t(&x).unwrap()) < 1e-4);
        assert!(p.matmul(&dy).unwrap().max_abs_diff(&d.matmul(&dy).unwrap()) < 1e-4);
        let w = d.to_dense();
        assert!(dense_matmul_t(&w, &x).unwrap().max_abs_diff(&w.matmul_t(&x).unwrap()) < 1e-3);
        // shape errors surface as errors, not panics
        assert!(p.matmul_t(&dy).is_err());
        assert!(dense_matmul_t(&w, &dy).is_err());
    }
}
