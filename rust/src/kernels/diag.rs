//! Offset-major diagonal SpMM — the native mirror of the L1 Pallas kernel.
//!
//! Layout (the §3.1 convention shared with `sparsity::diagonal` and
//! `python/compile/kernels/diag_matmul.py`): a `[n_out, n_in]` weight matrix
//! stores K selected diagonals; diagonal `off` owns entries
//! `(i, (i + off) mod n_in)`, and `values` is offset-major — `values[j *
//! n_out + i]` is the entry of diagonal `offsets[j]` at row `i`. Offset-major
//! storage makes all three training products stream `values` contiguously:
//!
//! * forward        `y  = x @ Wᵀ`    — [`spmm_t`]
//! * input grad     `dx = dy @ W`    — [`spmm`]
//! * weight grad    `dV[j,i] = Σ_b dy[b,i] · x[b, col(i,off_j)]` — [`grad_values`]
//!
//! The wrapped column index `(i + off) mod n_in` is maintained by a
//! carry counter instead of a `%` in the inner loop.

use super::pool::parallel_rows;

/// Forward product `y[b, n_out] = x[b, n_in] @ Wᵀ`. `y` is overwritten.
pub fn spmm_t(
    x: &[f32],
    offsets: &[usize],
    values: &[f32],
    y: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let k = offsets.len();
    assert_eq!(x.len(), b * n_in, "diag spmm_t: x length");
    assert_eq!(values.len(), k * n_out, "diag spmm_t: values length");
    assert_eq!(y.len(), b * n_out, "diag spmm_t: y length");
    y.fill(0.0);
    parallel_rows(y, n_out, 4, |first_row, y_chunk| {
        let rows = y_chunk.len() / n_out;
        for (j, &off) in offsets.iter().enumerate() {
            debug_assert!(off < n_in, "offset out of range");
            let vals = &values[j * n_out..(j + 1) * n_out];
            for r in 0..rows {
                let xr = &x[(first_row + r) * n_in..(first_row + r + 1) * n_in];
                let yr = &mut y_chunk[r * n_out..(r + 1) * n_out];
                let mut c = off % n_in;
                for i in 0..n_out {
                    yr[i] += vals[i] * xr[c];
                    c += 1;
                    if c == n_in {
                        c = 0;
                    }
                }
            }
        }
    });
}

/// Transposed product `dx[b, n_in] = dy[b, n_out] @ W` (the backward
/// input-gradient, still diagonal-wise — Apdx A). `dx` is overwritten.
pub fn spmm(
    dy: &[f32],
    offsets: &[usize],
    values: &[f32],
    dx: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let k = offsets.len();
    assert_eq!(dy.len(), b * n_out, "diag spmm: dy length");
    assert_eq!(values.len(), k * n_out, "diag spmm: values length");
    assert_eq!(dx.len(), b * n_in, "diag spmm: dx length");
    dx.fill(0.0);
    parallel_rows(dx, n_in, 4, |first_row, dx_chunk| {
        let rows = dx_chunk.len() / n_in;
        for (j, &off) in offsets.iter().enumerate() {
            let vals = &values[j * n_out..(j + 1) * n_out];
            for r in 0..rows {
                let dyr = &dy[(first_row + r) * n_out..(first_row + r + 1) * n_out];
                let dxr = &mut dx_chunk[r * n_in..(r + 1) * n_in];
                let mut c = off % n_in;
                for i in 0..n_out {
                    dxr[c] += vals[i] * dyr[i];
                    c += 1;
                    if c == n_in {
                        c = 0;
                    }
                }
            }
        }
    });
}

/// Weight gradient in offset-major layout: `dvalues[j, i] = Σ_b dy[b, i] ·
/// x[b, (i + offsets[j]) mod n_in]`. Parallel over diagonals (disjoint
/// `dvalues` rows). `dvalues` is overwritten.
pub fn grad_values(
    x: &[f32],
    dy: &[f32],
    offsets: &[usize],
    dvalues: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let k = offsets.len();
    assert_eq!(x.len(), b * n_in, "diag grad_values: x length");
    assert_eq!(dy.len(), b * n_out, "diag grad_values: dy length");
    assert_eq!(dvalues.len(), k * n_out, "diag grad_values: dvalues length");
    dvalues.fill(0.0);
    parallel_rows(dvalues, n_out, 1, |first_j, dv_chunk| {
        for (r, dvr) in dv_chunk.chunks_exact_mut(n_out).enumerate() {
            let off = offsets[first_j + r];
            for bi in 0..b {
                let xr = &x[bi * n_in..(bi + 1) * n_in];
                let dyr = &dy[bi * n_out..(bi + 1) * n_out];
                let mut c = off % n_in;
                for i in 0..n_out {
                    dvr[i] += dyr[i] * xr[c];
                    c += 1;
                    if c == n_in {
                        c = 0;
                    }
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use crate::sparsity::diagonal::DiagMatrix;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_diag(rng: &mut Rng, n_out: usize, n_in: usize, k: usize) -> DiagMatrix {
        let offsets = rng.choose_k(n_in, k);
        let mut d = DiagMatrix::new(n_out, n_in, offsets);
        for j in 0..d.k() {
            for i in 0..n_out {
                d.values[j][i] = rng.normal_f32(0.0, 1.0);
            }
        }
        d
    }

    fn pack(d: &DiagMatrix) -> Vec<f32> {
        let mut out = Vec::with_capacity(d.k() * d.n_out);
        for v in &d.values {
            out.extend_from_slice(v);
        }
        out
    }

    #[test]
    fn forward_matches_diag_matrix() {
        let mut rng = Rng::new(51);
        let (b, n_in, n_out, k) = (5usize, 12usize, 20usize, 4usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let mut y = vec![0.0f32; b * n_out];
        super::spmm_t(&x.data, &d.offsets, &pack(&d), &mut y, b, n_in, n_out);
        let want = d.matmul_t(&x).unwrap();
        let diff = want.data.iter().zip(&y).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-4, "diff {}", diff);
    }

    #[test]
    fn backward_matches_diag_matrix() {
        let mut rng = Rng::new(52);
        let (b, n_in, n_out, k) = (3usize, 10usize, 15usize, 6usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        let mut dx = vec![0.0f32; b * n_in];
        super::spmm(&dy.data, &d.offsets, &pack(&d), &mut dx, b, n_in, n_out);
        let want = d.matmul(&dy).unwrap();
        let diff = want.data.iter().zip(&dx).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-4, "diff {}", diff);
    }

    #[test]
    fn grad_values_matches_dense_chain() {
        let mut rng = Rng::new(53);
        let (b, n_in, n_out, k) = (4usize, 8usize, 16usize, 3usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        let mut dv = vec![0.0f32; k * n_out];
        super::grad_values(&x.data, &dy.data, &d.offsets, &mut dv, b, n_in, n_out);
        // reference: dW = dyᵀ @ x, then read the selected diagonals
        let dw = dy.transpose2().matmul(&x).unwrap();
        for (j, &off) in d.offsets.iter().enumerate() {
            for i in 0..n_out {
                let c = crate::sparsity::diagonal::diag_col(i, off, n_in);
                let want = dw.at2(i, c);
                let got = dv[j * n_out + i];
                assert!((want - got).abs() < 1e-4, "j={} i={}: {} vs {}", j, i, want, got);
            }
        }
    }
}
