//! Offset-major diagonal SpMM — the native mirror of the L1 Pallas kernel.
//!
//! Layout (the §3.1 convention shared with `sparsity::diagonal` and
//! `python/compile/kernels/diag_matmul.py`): a `[n_out, n_in]` weight matrix
//! stores K selected diagonals; diagonal `off` owns entries
//! `(i, (i + off) mod n_in)`, and `values` is offset-major — `values[j *
//! n_out + i]` is the entry of diagonal `offsets[j]` at row `i`. Offset-major
//! storage makes all three training products stream `values` contiguously:
//!
//! * forward        `y  = x @ Wᵀ`    — [`spmm_t`]
//! * input grad     `dx = dy @ W`    — [`spmm`]
//! * weight grad    `dV[j,i] = Σ_b dy[b,i] · x[b, col(i,off_j)]` — [`grad_values`]
//!
//! **Two-segment inner loops:** the wrapped column walk `(i + off) mod
//! n_in` splits each diagonal into contiguous sub-ranges where both sides
//! stream linearly (two segments when `n_out <= n_in`, `ceil` more when the
//! diagonal wraps repeatedly). Inside a segment the loop is a branch-free
//! element-wise FMA over three contiguous slices, executed by the
//! **dispatched SIMD microkernel** ([`super::microkernel`]): 8-wide AVX2
//! FMA, 4-wide NEON, or the scalar `mul_add` oracle — selected once per
//! process via `DYNADIAG_ISA` and bit-identical across paths.
//!
//! Every op has a `*_on(isa, ..)` twin taking an explicit
//! [`Isa`] so the parity harness (`tests/kernel_parity.rs`,
//! `tests/golden_diag_microkernel.rs`) and the per-ISA bench cells can
//! exercise every lane width on whatever host they run on.

use super::microkernel::{self, Isa, Microkernel, ScalarKernel};
use super::pool::{effective_threads, parallel_rows, TASK_GRAIN_FLOPS};

#[cfg(target_arch = "x86_64")]
use super::microkernel::Avx2Kernel;
#[cfg(target_arch = "aarch64")]
use super::microkernel::NeonKernel;

/// Monomorphize `$body` over the microkernel type `$mk` selected by
/// `$isa`. ISAs the current *build* cannot contain (e.g. `Neon` on
/// x86-64) fall through to scalar; runtime availability is the caller's
/// contract (`microkernel::sanitize` upholds it for the `*_on` entries,
/// `microkernel::active` for the dispatched ones).
macro_rules! with_isa {
    ($isa:expr, $mk:ident => $body:expr) => {
        match $isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => {
                type $mk = Avx2Kernel;
                $body
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => {
                type $mk = NeonKernel;
                $body
            }
            _ => {
                type $mk = ScalarKernel;
                $body
            }
        }
    };
}

/// `y[i] += v[i] * x[(i + off) mod n]` over `i in 0..y.len()`, decomposed
/// into contiguous wrap segments (`v.len() == y.len()`, `x.len() == n`),
/// each segment one microkernel `fma3` call.
#[inline]
fn fma_wrap_gather<M: Microkernel>(y: &mut [f32], v: &[f32], x: &[f32], off: usize) {
    let n_in = x.len();
    let n_out = y.len();
    debug_assert_eq!(v.len(), n_out);
    if n_in == 0 || n_out == 0 {
        return;
    }
    let mut i = 0usize;
    let mut c = off % n_in;
    while i < n_out {
        let seg = (n_out - i).min(n_in - c);
        M::fma3(&mut y[i..i + seg], &v[i..i + seg], &x[c..c + seg]);
        i += seg;
        c += seg;
        if c == n_in {
            c = 0;
        }
    }
}

/// `dx[(i + off) mod n] += v[i] * g[i]` over `i in 0..g.len()` — the
/// scatter twin of [`fma_wrap_gather`] (`v.len() == g.len()`,
/// `dx.len() == n`).
#[inline]
fn fma_wrap_scatter<M: Microkernel>(dx: &mut [f32], v: &[f32], g: &[f32], off: usize) {
    let n_in = dx.len();
    let n_out = g.len();
    debug_assert_eq!(v.len(), n_out);
    if n_in == 0 || n_out == 0 {
        return;
    }
    let mut i = 0usize;
    let mut c = off % n_in;
    while i < n_out {
        let seg = (n_out - i).min(n_in - c);
        M::fma3(&mut dx[c..c + seg], &v[i..i + seg], &g[i..i + seg]);
        i += seg;
        c += seg;
        if c == n_in {
            c = 0;
        }
    }
}

fn spmm_t_impl<M: Microkernel>(
    x: &[f32],
    offsets: &[usize],
    values: &[f32],
    y: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let k = offsets.len();
    assert_eq!(x.len(), b * n_in, "diag spmm_t: x length");
    assert_eq!(values.len(), k * n_out, "diag spmm_t: values length");
    assert_eq!(y.len(), b * n_out, "diag spmm_t: y length");
    y.fill(0.0);
    parallel_rows(y, n_out, 2 * k * n_out, |first_row, y_chunk| {
        for (r, yr) in y_chunk.chunks_exact_mut(n_out).enumerate() {
            let xr = &x[(first_row + r) * n_in..(first_row + r + 1) * n_in];
            for (j, &off) in offsets.iter().enumerate() {
                debug_assert!(off < n_in, "offset out of range");
                let vals = &values[j * n_out..(j + 1) * n_out];
                fma_wrap_gather::<M>(yr, vals, xr, off);
            }
        }
    });
}

/// Forward product `y[b, n_out] = x[b, n_in] @ Wᵀ`. `y` is overwritten.
/// Runs on the process-wide dispatched ISA ([`microkernel::active`]).
pub fn spmm_t(
    x: &[f32],
    offsets: &[usize],
    values: &[f32],
    y: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    spmm_t_on(microkernel::active(), x, offsets, values, y, b, n_in, n_out);
}

/// [`spmm_t`] forced onto a specific ISA path (parity harness / per-ISA
/// bench cells). An ISA this host cannot execute runs the scalar path —
/// the same degradation contract as `DYNADIAG_ISA` forcing.
pub fn spmm_t_on(
    isa: Isa,
    x: &[f32],
    offsets: &[usize],
    values: &[f32],
    y: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let isa = microkernel::sanitize(isa);
    with_isa!(isa, M => spmm_t_impl::<M>(x, offsets, values, y, b, n_in, n_out))
}

fn spmm_impl<M: Microkernel>(
    dy: &[f32],
    offsets: &[usize],
    values: &[f32],
    dx: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let k = offsets.len();
    assert_eq!(dy.len(), b * n_out, "diag spmm: dy length");
    assert_eq!(values.len(), k * n_out, "diag spmm: values length");
    assert_eq!(dx.len(), b * n_in, "diag spmm: dx length");
    dx.fill(0.0);
    parallel_rows(dx, n_in, 2 * k * n_out, |first_row, dx_chunk| {
        for (r, dxr) in dx_chunk.chunks_exact_mut(n_in).enumerate() {
            let dyr = &dy[(first_row + r) * n_out..(first_row + r + 1) * n_out];
            for (j, &off) in offsets.iter().enumerate() {
                let vals = &values[j * n_out..(j + 1) * n_out];
                fma_wrap_scatter::<M>(dxr, vals, dyr, off);
            }
        }
    });
}

/// Transposed product `dx[b, n_in] = dy[b, n_out] @ W` (the backward
/// input-gradient, still diagonal-wise — Apdx A). `dx` is overwritten.
pub fn spmm(
    dy: &[f32],
    offsets: &[usize],
    values: &[f32],
    dx: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    spmm_on(microkernel::active(), dy, offsets, values, dx, b, n_in, n_out);
}

/// [`spmm`] forced onto a specific ISA path.
pub fn spmm_on(
    isa: Isa,
    dy: &[f32],
    offsets: &[usize],
    values: &[f32],
    dx: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let isa = microkernel::sanitize(isa);
    with_isa!(isa, M => spmm_impl::<M>(dy, offsets, values, dx, b, n_in, n_out))
}

/// Epilogue applied per output element by the fused forward
/// [`spmm_t_bias`] (serving path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Epilogue {
    /// bias add only
    None,
    /// bias add then tanh-approximation GELU
    Gelu,
}

fn spmm_t_bias_impl<M: Microkernel>(
    x: &[f32],
    offsets: &[usize],
    values: &[f32],
    bias: &[f32],
    y: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
    epilogue: Epilogue,
) {
    let k = offsets.len();
    assert_eq!(x.len(), b * n_in, "diag spmm_t_bias: x length");
    assert_eq!(values.len(), k * n_out, "diag spmm_t_bias: values length");
    assert_eq!(bias.len(), n_out, "diag spmm_t_bias: bias length");
    assert_eq!(y.len(), b * n_out, "diag spmm_t_bias: y length");
    parallel_rows(y, n_out, 2 * (k + 1) * n_out, |first_row, y_chunk| {
        for (r, yr) in y_chunk.chunks_exact_mut(n_out).enumerate() {
            let xr = &x[(first_row + r) * n_in..(first_row + r + 1) * n_in];
            yr.copy_from_slice(bias);
            for (j, &off) in offsets.iter().enumerate() {
                debug_assert!(off < n_in, "offset out of range");
                let vals = &values[j * n_out..(j + 1) * n_out];
                fma_wrap_gather::<M>(yr, vals, xr, off);
            }
            // the activation stays scalar libm on every ISA, so the
            // epilogue can never diverge between lane widths
            if epilogue == Epilogue::Gelu {
                for v in yr.iter_mut() {
                    *v = super::gelu(*v);
                }
            }
        }
    });
}

/// Fused serving forward: `y = act(x @ Wᵀ + bias)` in a single pass over
/// `y` — each output row is seeded with the bias vector, accumulates every
/// selected diagonal, then applies the epilogue in-place. Compared to the
/// train-path sequence (`spmm_t`, then a bias sweep, then an activation
/// sweep) this touches `y` once instead of three times, which matters at
/// serving batch sizes where the whole batch fits in L1/L2. (Because the
/// bias seeds the accumulator here but is added *last* on the train path,
/// the two paths can differ in the final ulps — the serving-side contract
/// is fused-vs-fused determinism, pinned bitwise below and in
/// `tests/serve_parity.rs`.)
///
/// **Dispatch grain:** rows (requests) are independent, so per-row results
/// are bit-identical no matter how requests are coalesced — a batch of 1
/// always runs inline (no pool wakeup on the latency path), while a
/// coalesced micro-batch fans out across the worker pool once its flop
/// count clears the grain. `rust/tests/serve_parity.rs` pins the
/// batched == sequential bitwise contract.
pub fn spmm_t_bias(
    x: &[f32],
    offsets: &[usize],
    values: &[f32],
    bias: &[f32],
    y: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
    epilogue: Epilogue,
) {
    spmm_t_bias_on(
        microkernel::active(),
        x,
        offsets,
        values,
        bias,
        y,
        b,
        n_in,
        n_out,
        epilogue,
    );
}

/// [`spmm_t_bias`] forced onto a specific ISA path.
#[allow(clippy::too_many_arguments)]
pub fn spmm_t_bias_on(
    isa: Isa,
    x: &[f32],
    offsets: &[usize],
    values: &[f32],
    bias: &[f32],
    y: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
    epilogue: Epilogue,
) {
    let isa = microkernel::sanitize(isa);
    with_isa!(
        isa,
        M => spmm_t_bias_impl::<M>(x, offsets, values, bias, y, b, n_in, n_out, epilogue)
    )
}

thread_local! {
    /// Reused partial-accumulator scratch for the batch-split path of
    /// [`grad_values`] (no per-call allocation after warmup).
    static GRAD_SCRATCH: std::cell::RefCell<Vec<f32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

fn grad_values_impl<M: Microkernel>(
    x: &[f32],
    dy: &[f32],
    offsets: &[usize],
    dvalues: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let k = offsets.len();
    assert_eq!(x.len(), b * n_in, "diag grad_values: x length");
    assert_eq!(dy.len(), b * n_out, "diag grad_values: dy length");
    assert_eq!(dvalues.len(), k * n_out, "diag grad_values: dvalues length");
    dvalues.fill(0.0);

    let threads = effective_threads();
    let total_flops = 2usize
        .saturating_mul(b)
        .saturating_mul(k)
        .saturating_mul(n_out);
    if threads > 1 && k < threads && b >= 2 && total_flops >= 2 * TASK_GRAIN_FLOPS {
        // batch split with per-worker partials + reduction
        let parts = threads.min(b);
        let b_chunk = b.div_ceil(parts);
        GRAD_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.clear();
            scratch.resize(parts * k * n_out, 0.0);
            parallel_rows(
                scratch.as_mut_slice(),
                k * n_out,
                2 * b_chunk * k * n_out,
                |first_part, chunk| {
                    for (pi, dvp) in chunk.chunks_exact_mut(k * n_out).enumerate() {
                        let part = first_part + pi;
                        let b0 = part * b_chunk;
                        let b1 = (b0 + b_chunk).min(b);
                        for bi in b0..b1 {
                            let xr = &x[bi * n_in..(bi + 1) * n_in];
                            let dyr = &dy[bi * n_out..(bi + 1) * n_out];
                            for (j, &off) in offsets.iter().enumerate() {
                                fma_wrap_gather::<M>(
                                    &mut dvp[j * n_out..(j + 1) * n_out],
                                    dyr,
                                    xr,
                                    off,
                                );
                            }
                        }
                    }
                },
            );
            // partials reduce in part order: ISA-independent (plain adds),
            // thread-count-dependent (documented in kernels::mod)
            for part in scratch.chunks_exact(k * n_out) {
                for (o, &v) in dvalues.iter_mut().zip(part) {
                    *o += v;
                }
            }
        });
        return;
    }

    // enough diagonals: split over disjoint dvalues rows
    parallel_rows(dvalues, n_out, 2 * b * n_out, |first_j, dv_chunk| {
        for (r, dvr) in dv_chunk.chunks_exact_mut(n_out).enumerate() {
            let off = offsets[first_j + r];
            for bi in 0..b {
                let xr = &x[bi * n_in..(bi + 1) * n_in];
                let dyr = &dy[bi * n_out..(bi + 1) * n_out];
                fma_wrap_gather::<M>(dvr, dyr, xr, off);
            }
        }
    });
}

/// Weight gradient in offset-major layout: `dvalues[j, i] = Σ_b dy[b, i] ·
/// x[b, (i + offsets[j]) mod n_in]`. `dvalues` is overwritten.
///
/// Two parallel strategies: when there are enough diagonals, split over
/// them (disjoint `dvalues` rows). When `k` is below the thread count —
/// the common case at high sparsity, where the old kernel degenerated to a
/// near-serial loop — split over the **batch** dimension instead: each
/// worker accumulates a private partial `dvalues` over its batch slice,
/// followed by a single reduction. Both strategies accumulate the batch
/// dimension in index order per element, so results are bit-identical
/// across ISAs at any fixed thread count.
pub fn grad_values(
    x: &[f32],
    dy: &[f32],
    offsets: &[usize],
    dvalues: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    grad_values_on(microkernel::active(), x, dy, offsets, dvalues, b, n_in, n_out);
}

/// [`grad_values`] forced onto a specific ISA path.
pub fn grad_values_on(
    isa: Isa,
    x: &[f32],
    dy: &[f32],
    offsets: &[usize],
    dvalues: &mut [f32],
    b: usize,
    n_in: usize,
    n_out: usize,
) {
    let isa = microkernel::sanitize(isa);
    with_isa!(isa, M => grad_values_impl::<M>(x, dy, offsets, dvalues, b, n_in, n_out))
}

#[cfg(test)]
mod tests {
    use crate::kernels::microkernel;
    use crate::sparsity::diagonal::DiagMatrix;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn random_diag(rng: &mut Rng, n_out: usize, n_in: usize, k: usize) -> DiagMatrix {
        let offsets = rng.choose_k(n_in, k);
        let mut d = DiagMatrix::new(n_out, n_in, offsets);
        for j in 0..d.k() {
            for i in 0..n_out {
                d.values[j][i] = rng.normal_f32(0.0, 1.0);
            }
        }
        d
    }

    fn pack(d: &DiagMatrix) -> Vec<f32> {
        let mut out = Vec::with_capacity(d.k() * d.n_out);
        for v in &d.values {
            out.extend_from_slice(v);
        }
        out
    }

    #[test]
    fn forward_matches_diag_matrix() {
        let mut rng = Rng::new(51);
        let (b, n_in, n_out, k) = (5usize, 12usize, 20usize, 4usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let mut y = vec![0.0f32; b * n_out];
        super::spmm_t(&x.data, &d.offsets, &pack(&d), &mut y, b, n_in, n_out);
        let want = d.matmul_t(&x).unwrap();
        let diff = want.data.iter().zip(&y).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-4, "diff {}", diff);
    }

    #[test]
    fn backward_matches_diag_matrix() {
        let mut rng = Rng::new(52);
        let (b, n_in, n_out, k) = (3usize, 10usize, 15usize, 6usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        let mut dx = vec![0.0f32; b * n_in];
        super::spmm(&dy.data, &d.offsets, &pack(&d), &mut dx, b, n_in, n_out);
        let want = d.matmul(&dy).unwrap();
        let diff = want.data.iter().zip(&dx).fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-4, "diff {}", diff);
    }

    #[test]
    fn grad_values_matches_dense_chain() {
        let mut rng = Rng::new(53);
        let (b, n_in, n_out, k) = (4usize, 8usize, 16usize, 3usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        let mut dv = vec![0.0f32; k * n_out];
        super::grad_values(&x.data, &dy.data, &d.offsets, &mut dv, b, n_in, n_out);
        // reference: dW = dyᵀ @ x, then read the selected diagonals
        let dw = dy.transpose2().matmul(&x).unwrap();
        for (j, &off) in d.offsets.iter().enumerate() {
            for i in 0..n_out {
                let c = crate::sparsity::diagonal::diag_col(i, off, n_in);
                let want = dw.at2(i, c);
                let got = dv[j * n_out + i];
                assert!((want - got).abs() < 1e-4, "j={} i={}: {} vs {}", j, i, want, got);
            }
        }
    }

    /// The fused bias+activation forward tracks the unfused sequence to
    /// float tolerance (the bias seeds the accumulator when fused but is
    /// added last when unfused, so the final ulps may differ) and is
    /// bitwise batch-invariant (the serving parity contract).
    #[test]
    fn spmm_t_bias_matches_unfused_and_is_batch_invariant() {
        let mut rng = Rng::new(55);
        let (b, n_in, n_out, k) = (6usize, 12usize, 20usize, 4usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let bias: Vec<f32> = (0..n_out).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for epi in [super::Epilogue::None, super::Epilogue::Gelu] {
            let mut fused = vec![0.0f32; b * n_out];
            super::spmm_t_bias(
                &x.data, &d.offsets, &pack(&d), &bias, &mut fused, b, n_in, n_out, epi,
            );
            // unfused reference: spmm_t, then bias, then activation
            let mut want = vec![0.0f32; b * n_out];
            super::spmm_t(&x.data, &d.offsets, &pack(&d), &mut want, b, n_in, n_out);
            for row in want.chunks_exact_mut(n_out) {
                for (v, &bb) in row.iter_mut().zip(&bias) {
                    *v += bb;
                }
                if epi == super::Epilogue::Gelu {
                    for v in row.iter_mut() {
                        *v = crate::kernels::gelu(*v);
                    }
                }
            }
            let diff = fused
                .iter()
                .zip(&want)
                .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
            assert!(diff < 1e-5, "fused drifted {} from unfused for {:?}", diff, epi);
            // batch-of-1 rows must be bitwise identical to the batched rows
            for bi in 0..b {
                let mut one = vec![0.0f32; n_out];
                super::spmm_t_bias(
                    &x.data[bi * n_in..(bi + 1) * n_in],
                    &d.offsets,
                    &pack(&d),
                    &bias,
                    &mut one,
                    1,
                    n_in,
                    n_out,
                    epi,
                );
                assert_eq!(one, &fused[bi * n_out..(bi + 1) * n_out], "row {}", bi);
            }
        }
    }

    /// The batch-split path (k < threads, b large) must agree with the
    /// diagonal-split path and the dense chain.
    #[test]
    fn grad_values_batch_split_matches_dense_chain() {
        let mut rng = Rng::new(54);
        // k=1 forces the batch split whenever more than one thread exists;
        // sized so total flops clear the parallel grain
        let (b, n_in, n_out, k) = (64usize, 96usize, 1024usize, 1usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        let mut dv = vec![0.0f32; k * n_out];
        super::grad_values(&x.data, &dy.data, &d.offsets, &mut dv, b, n_in, n_out);
        let dw = dy.transpose2().matmul(&x).unwrap();
        for (j, &off) in d.offsets.iter().enumerate() {
            for i in 0..n_out {
                let c = crate::sparsity::diagonal::diag_col(i, off, n_in);
                let want = dw.at2(i, c);
                let got = dv[j * n_out + i];
                assert!((want - got).abs() < 1e-3, "j={} i={}: {} vs {}", j, i, want, got);
            }
        }
    }

    /// The dispatched path and every explicitly forced path agree bitwise
    /// on the forward product (the deeper sweep lives in
    /// `tests/kernel_parity.rs`; this is the in-crate smoke check).
    #[test]
    fn forced_isa_paths_match_dispatched_bitwise() {
        let mut rng = Rng::new(56);
        let (b, n_in, n_out, k) = (3usize, 13usize, 29usize, 5usize);
        let d = random_diag(&mut rng, n_out, n_in, k);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let mut want = vec![0.0f32; b * n_out];
        super::spmm_t(&x.data, &d.offsets, &pack(&d), &mut want, b, n_in, n_out);
        for &isa in microkernel::available() {
            let mut got = vec![0.0f32; b * n_out];
            super::spmm_t_on(isa, &x.data, &d.offsets, &pack(&d), &mut got, b, n_in, n_out);
            let same = got
                .iter()
                .zip(&want)
                .all(|(g, w)| g.to_bits() == w.to_bits());
            assert!(same, "{} diverges from the dispatched path", isa.name());
        }
    }
}
