//! Cache-blocked dense GEMM reference: `y = x @ Wᵀ` with `W: [n_out, n_in]`.
//!
//! This is the baseline every sparse kernel races against (Fig 7's
//! denominator). Layout choices:
//! * parallel over batch rows (disjoint `y` rows, shared read-only `W`),
//! * 8-way output-row register blocking (with a 4-way and scalar tail) so
//!   each `x` element is reused from registers across eight simultaneous
//!   dot products,
//! * `KC`-blocking over the reduction dim so the active `x` / `W` panels
//!   stay in L1/L2 for the larger layer shapes.
//!
//! **Deliberately outside the [`super::microkernel`] ISA dispatch.** The
//! dense products are *reductions* — hand-vectorizing them per ISA would
//! change the summation tree per lane width and break the repo's
//! cross-ISA determinism story (the embed/head layers of every served
//! model run through here, so keeping them compile-time-fixed is what
//! makes whole-model logits bit-identical under any `DYNADIAG_ISA`).
//! They are also the bench *baseline*: dispatching the denominator would
//! let the numerator's speedup ride along with it. Autovectorization of
//! the blocked loops below is stable and fast enough for that role.

use super::pool::parallel_rows;

/// Reduction-dimension block size (f32 elements).
const KC: usize = 1024;

/// `y[b, n_out] = x[b, n_in] @ w[n_out, n_in]ᵀ`. `y` is fully overwritten.
pub fn gemm_t(x: &[f32], w: &[f32], y: &mut [f32], b: usize, n_in: usize, n_out: usize) {
    assert_eq!(x.len(), b * n_in, "gemm_t: x length");
    assert_eq!(w.len(), n_out * n_in, "gemm_t: w length");
    assert_eq!(y.len(), b * n_out, "gemm_t: y length");
    y.fill(0.0);
    parallel_rows(y, n_out, 2 * n_in * n_out, |first_row, y_chunk| {
        let x_chunk = &x[first_row * n_in..first_row * n_in + (y_chunk.len() / n_out) * n_in];
        gemm_t_chunk(x_chunk, w, y_chunk, n_in, n_out);
    });
}

fn gemm_t_chunk(x: &[f32], w: &[f32], y: &mut [f32], n_in: usize, n_out: usize) {
    for k0 in (0..n_in).step_by(KC) {
        let kc = KC.min(n_in - k0);
        for (xr, yr) in x.chunks_exact(n_in).zip(y.chunks_exact_mut(n_out)) {
            let xk = &xr[k0..k0 + kc];
            let mut oi = 0;
            // 8-way register blocking over output rows: eight unrolled
            // accumulators reuse each x element from a register
            while oi + 8 <= n_out {
                let rows: [&[f32]; 8] = std::array::from_fn(|u| {
                    &w[(oi + u) * n_in + k0..(oi + u) * n_in + k0 + kc]
                });
                let mut acc = [0.0f32; 8];
                for c in 0..kc {
                    let xv = xk[c];
                    for u in 0..8 {
                        acc[u] += xv * rows[u][c];
                    }
                }
                for u in 0..8 {
                    yr[oi + u] += acc[u];
                }
                oi += 8;
            }
            // 4-way tail
            while oi + 4 <= n_out {
                let w0 = &w[oi * n_in + k0..oi * n_in + k0 + kc];
                let w1 = &w[(oi + 1) * n_in + k0..(oi + 1) * n_in + k0 + kc];
                let w2 = &w[(oi + 2) * n_in + k0..(oi + 2) * n_in + k0 + kc];
                let w3 = &w[(oi + 3) * n_in + k0..(oi + 3) * n_in + k0 + kc];
                let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for c in 0..kc {
                    let xv = xk[c];
                    a0 += xv * w0[c];
                    a1 += xv * w1[c];
                    a2 += xv * w2[c];
                    a3 += xv * w3[c];
                }
                yr[oi] += a0;
                yr[oi + 1] += a1;
                yr[oi + 2] += a2;
                yr[oi + 3] += a3;
                oi += 4;
            }
            while oi < n_out {
                let wr = &w[oi * n_in + k0..oi * n_in + k0 + kc];
                let mut acc = 0.0f32;
                for c in 0..kc {
                    acc += xk[c] * wr[c];
                }
                yr[oi] += acc;
                oi += 1;
            }
        }
    }
}

/// `dw[n_out, n_in] = dyᵀ @ x` — the weight-gradient product of a linear
/// layer (`dy: [b, n_out]`, `x: [b, n_in]`). `dw` is fully overwritten.
pub fn gemm_grad_w(dy: &[f32], x: &[f32], dw: &mut [f32], b: usize, n_in: usize, n_out: usize) {
    assert_eq!(dy.len(), b * n_out, "gemm_grad_w: dy length");
    assert_eq!(x.len(), b * n_in, "gemm_grad_w: x length");
    assert_eq!(dw.len(), n_out * n_in, "gemm_grad_w: dw length");
    dw.fill(0.0);
    parallel_rows(dw, n_in, 2 * b * n_in, |first_out, dw_chunk| {
        for (r, dwr) in dw_chunk.chunks_exact_mut(n_in).enumerate() {
            let oi = first_out + r;
            for bi in 0..b {
                let g = dy[bi * n_out + oi];
                if g == 0.0 {
                    continue;
                }
                let xr = &x[bi * n_in..(bi + 1) * n_in];
                for c in 0..n_in {
                    dwr[c] += g * xr[c];
                }
            }
        }
    });
}

/// `dx[b, n_in] = dy[b, n_out] @ w[n_out, n_in]` — the input-gradient
/// product. `dx` is fully overwritten.
pub fn gemm(dy: &[f32], w: &[f32], dx: &mut [f32], b: usize, n_in: usize, n_out: usize) {
    assert_eq!(dy.len(), b * n_out, "gemm: dy length");
    assert_eq!(w.len(), n_out * n_in, "gemm: w length");
    assert_eq!(dx.len(), b * n_in, "gemm: dx length");
    dx.fill(0.0);
    parallel_rows(dx, n_in, 2 * n_out * n_in, |first_row, dx_chunk| {
        for (r, dxr) in dx_chunk.chunks_exact_mut(n_in).enumerate() {
            let dyr = &dy[(first_row + r) * n_out..(first_row + r + 1) * n_out];
            for (oi, &g) in dyr.iter().enumerate() {
                if g == 0.0 {
                    continue;
                }
                let wr = &w[oi * n_in..(oi + 1) * n_in];
                for c in 0..n_in {
                    dxr[c] += g * wr[c];
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn gemm_t_matches_tensor_reference() {
        let mut rng = Rng::new(41);
        for &(b, n_in, n_out) in &[(1usize, 7usize, 5usize), (3, 17, 23), (8, 130, 67)] {
            let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
            let w = Tensor::randn(&[n_out, n_in], 1.0, &mut rng);
            let mut y = vec![0.0f32; b * n_out];
            super::gemm_t(&x.data, &w.data, &mut y, b, n_in, n_out);
            let want = w.matmul_t(&x).unwrap();
            let diff = want
                .data
                .iter()
                .zip(&y)
                .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
            assert!(diff < 1e-3, "b={} n_in={} n_out={}: diff {}", b, n_in, n_out, diff);
        }
    }

    #[test]
    fn gemm_matches_tensor_reference() {
        let mut rng = Rng::new(42);
        let (b, n_in, n_out) = (4usize, 19usize, 11usize);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        let w = Tensor::randn(&[n_out, n_in], 1.0, &mut rng);
        let mut dx = vec![0.0f32; b * n_in];
        super::gemm(&dy.data, &w.data, &mut dx, b, n_in, n_out);
        let want = dy.matmul(&w).unwrap();
        let diff = want
            .data
            .iter()
            .zip(&dx)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-3, "diff {}", diff);
    }

    #[test]
    fn grad_w_matches_tensor_reference() {
        let mut rng = Rng::new(43);
        let (b, n_in, n_out) = (6usize, 13usize, 9usize);
        let dy = Tensor::randn(&[b, n_out], 1.0, &mut rng);
        let x = Tensor::randn(&[b, n_in], 1.0, &mut rng);
        let mut dw = vec![0.0f32; n_out * n_in];
        super::gemm_grad_w(&dy.data, &x.data, &mut dw, b, n_in, n_out);
        let want = dy.transpose2().matmul(&x).unwrap();
        let diff = want
            .data
            .iter()
            .zip(&dw)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(diff < 1e-3, "diff {}", diff);
    }
}
