//! Offline stand-in for the `anyhow` crate, covering the subset the
//! `dynadiag` crate uses: an erased error type with context chaining, the
//! `anyhow!` / `bail!` macros, the `Context` extension trait, and the
//! `Result<T>` alias.
//!
//! Like real `anyhow`, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that keeps the blanket `From<E: std::error::Error>`
//! impl coherent with the reflexive `From<Error> for Error`.

use std::fmt;

/// An erased error: a chain of human-readable messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn push_context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    /// `{}` shows the outermost message; `{:#}` shows the whole chain
    /// joined by `: ` (matching anyhow's alternate formatting).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{}", head)?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {}: {}", i, c)?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors as they bubble up.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(format!("{}", e), "reading manifest");
        assert_eq!(format!("{:#}", e), "reading manifest: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn macros_and_option_context() {
        fn f() -> Result<()> {
            bail!("bad value {}", 7);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "bad value 7");
        let none: Option<u8> = None;
        assert!(none.context("missing").is_err());
        let got: Result<u8> = Some(3u8).with_context(|| "unused");
        assert_eq!(got.unwrap(), 3);
    }
}
