//! Headless stub of the `xla-rs` PJRT binding surface `dynadiag` compiles
//! against. The offline build environment has no XLA shared library, so
//! every entry point fails at *runtime* with a clear message while the
//! `XlaBackend` code keeps compiling unchanged. Replacing this crate with
//! the real bindings (same module path, same signatures) re-enables the
//! artifact execution path — see docs/ARCHITECTURE.md §Backends.
//!
//! Only [`PjRtClient::cpu`] is reachable in practice: it errors, so no
//! executable or literal ever flows through the other methods.

use std::fmt;

/// Error surfaced by every stubbed entry point.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "XLA/PJRT runtime is not linked in this build; \
     use the native backend (--backend native) or replace rust/vendor/xla \
     with the real xla-rs bindings";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Buffer element types the dynadiag manifest can carry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    F32,
    F64,
}

/// Shape of an array literal: dims + element type.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal (stub: carries no data).
#[derive(Clone, Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Clone>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module (stub: never constructed successfully).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("native backend"), "{}", msg);
    }
}
