//! `cargo bench --bench fig7_diag_speed` — regenerates Fig 7 — speedup vs
//! #diagonals (768×768).
//!
//! Runs the experiment in its `--fast` profile (fewer steps/batches) so the
//! whole bench suite finishes on one core; `dynadiag experiment fig7` runs
//! the full-size version. Works with either backend: XLA when `make
//! artifacts` has produced compiled micro kernels, the native kernel
//! subsystem otherwise.

use std::rc::Rc;

fn main() {
    let session = dynadiag::runtime::Session::open("artifacts").expect("opening session");
    eprintln!("fig7 bench via the {} backend", session.backend_name());
    let opts = dynadiag::experiments::ExpOpts { steps: None, seeds: 1, fast: true };
    run(&session, &opts).unwrap();
}

fn run(
    session: &Rc<dynadiag::runtime::Session>,
    opts: &dynadiag::experiments::ExpOpts,
) -> anyhow::Result<()> {
    dynadiag::experiments::fig7::run(session, opts)
}
