//! `cargo bench --bench serve` — the online-serving load sweep + gates.
//!
//! Sweeps request rate × micro-batch ceiling × sparsity over the serving
//! engine (synthetic finalized models, closed-loop warmup before every
//! measured cell), printing a table and writing
//! `results/serve_bench.json`. `BENCH_serve.json` at the repo root is the
//! committed schema/baseline snapshot.
//!
//! Three gates make this a CI check (`serve-smoke`), not just a report:
//!
//! 1. **Parity** — batched serving output must be *bitwise* identical to
//!    sequential single-request inference for the same requests (the
//!    micro-batcher must be invisible). Mismatch exits 1.
//! 2. **Steady-state allocations** — the measured window of every cell
//!    must perform zero fresh workspace allocations (the arena contract).
//!    Violation exits 1.
//! 3. **p99 ceiling** — every cell's p99 must stay under
//!    `DYNADIAG_SERVE_P99_MS` (default 250 ms — generous, catches
//!    order-of-magnitude regressions without flaking on shared runners).
//!
//! Set `DYNADIAG_BENCH_FAST=1` (CI does) for a trimmed sweep with the
//! same JSON schema.

use dynadiag::runtime::infer::{mlp_config, DiagModel};
use dynadiag::runtime::native::workspace;
use dynadiag::serve::{
    drive_load, BatchPolicy, Completion, LoadSpec, ManualClock, ServeEngine,
};
use dynadiag::util::json::Json;
use dynadiag::util::rng::Rng;

/// Batched-vs-sequential parity over one (sparsity, ceiling) point:
/// submit `n` requests, flush through the engine at the given ceiling,
/// and compare every completion bitwise against a direct batch-of-1
/// forward of the same sample. Returns the number of mismatched requests.
fn parity_mismatches(sparsity: f64, max_batch: usize, n: usize, seed: u64) -> usize {
    let cfg = mlp_config("mlp_micro").unwrap();
    let model = DiagModel::synth(cfg, sparsity, seed);
    let sl = model.sample_len();
    let classes = model.classes();
    let mut rng = Rng::new(seed ^ 0xbeef);
    let samples: Vec<Vec<f32>> =
        (0..n).map(|_| (0..sl).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();

    // huge deadline: batches form purely by ceiling, remainder via flush
    let mut engine = ServeEngine::new(
        model.clone(),
        BatchPolicy::new(max_batch, u64::MAX / 2).unwrap(),
    );
    let clock = ManualClock::new();
    let mut out: Vec<Completion> = Vec::new();
    for s in &samples {
        engine.submit(workspace::take_copy_f32(s), &clock).unwrap();
        engine.poll(&clock, &mut out).unwrap();
    }
    while engine.queue_len() > 0 {
        engine.flush(&clock, &mut out).unwrap();
    }
    assert_eq!(out.len(), n, "all requests must complete");

    let mut mismatches = 0usize;
    for c in out.drain(..) {
        let want = model.forward_logits(&samples[c.id as usize], 1).unwrap();
        if c.logits != want {
            mismatches += 1;
        }
        workspace::give_f32(want);
        workspace::give_f32(c.logits);
    }
    mismatches
}

fn main() {
    let fast = std::env::var("DYNADIAG_BENCH_FAST")
        .map(|v| !v.is_empty() && v != "0" && v.to_ascii_lowercase() != "false")
        .unwrap_or(false);
    let p99_bound_ms: f64 = std::env::var("DYNADIAG_SERVE_P99_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250.0);

    // -- gate 1: parity --------------------------------------------------
    println!("== serving parity: batched == sequential (bitwise) ==");
    let mut parity_failed = false;
    for &s in &[0.5, 0.9] {
        for &c in &[1usize, 3, 8] {
            let bad = parity_mismatches(s, c, 32, 1000 + (s * 10.0) as u64 + c as u64);
            println!("  sparsity {:.2} ceiling {}: {}", s, c, if bad == 0 { "ok".to_string() } else { format!("{} MISMATCHES", bad) });
            if bad > 0 {
                parity_failed = true;
            }
        }
    }
    if parity_failed {
        eprintln!("FAIL: batched serving diverged from sequential inference");
        std::process::exit(1);
    }

    // -- the sweep -------------------------------------------------------
    let models: &[&str] = if fast { &["mlp_micro"] } else { &["mlp_micro", "mlp_tiny"] };
    let sparsities: &[f64] = if fast { &[0.9] } else { &[0.5, 0.9] };
    let ceilings: &[usize] = if fast { &[1, 8] } else { &[1, 4, 8, 16] };
    let rates: &[f64] = if fast { &[0.0, 4000.0] } else { &[0.0, 1000.0, 4000.0, 16000.0] };
    let requests = if fast { 256 } else { 2048 };
    let max_wait_us: u64 = 200;

    println!("\n== serving sweep: rate x batch ceiling x sparsity{} ==", if fast { " [fast]" } else { "" });
    println!(
        "{:<10} {:>8} {:>7} {:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>6} {:>6}",
        "model", "sparsity", "ceiling", "rate", "thru rps", "p50 ms", "p95 ms", "p99 ms", "mean ms", "batch", "fresh"
    );
    let mut cells: Vec<Json> = Vec::new();
    let mut alloc_failed = false;
    let mut p99_failed = false;
    for model_name in models {
        let cfg = mlp_config(model_name).unwrap();
        for &s in sparsities {
            for &ceil in ceilings {
                let dm = DiagModel::synth(cfg, s, 7_000 + (s * 100.0) as u64);
                let mut engine =
                    ServeEngine::new(dm, BatchPolicy::new(ceil, max_wait_us).unwrap());
                // warm the arena at the SAME admission cap as the measured
                // windows — the closed loop bursts to the full cap of
                // payload buffers before the first flush, so a smaller
                // warmup cap would leave the measured window allocating
                let cap = (4 * ceil).max(16);
                let warm = LoadSpec {
                    requests: 2 * cap,
                    rate_rps: 0.0,
                    max_outstanding: cap,
                    seed: 5,
                };
                drive_load(&mut engine, &warm).unwrap();
                for &rate in rates {
                    engine.reset_metrics();
                    let spec = LoadSpec {
                        requests,
                        rate_rps: rate,
                        max_outstanding: cap,
                        seed: 11,
                    };
                    let r = drive_load(&mut engine, &spec).unwrap();
                    println!(
                        "{:<10} {:>7.0}% {:>7} {:>9} {:>9.0} {:>9.3} {:>8.3} {:>8.3} {:>8.3} {:>6.2} {:>6}",
                        model_name,
                        s * 100.0,
                        ceil,
                        if rate > 0.0 { format!("{:.0}", rate) } else { "closed".to_string() },
                        r.throughput_rps,
                        r.p50_ms,
                        r.p95_ms,
                        r.p99_ms,
                        r.mean_ms,
                        r.mean_batch,
                        r.fresh_allocs
                    );
                    if r.fresh_allocs > 0 {
                        alloc_failed = true;
                    }
                    if r.p99_ms > p99_bound_ms {
                        p99_failed = true;
                    }
                    let mut cell = std::collections::BTreeMap::new();
                    cell.insert("model".to_string(), Json::Str(model_name.to_string()));
                    cell.insert("sparsity".to_string(), Json::Num(s));
                    cell.insert("max_batch".to_string(), Json::Num(ceil as f64));
                    cell.insert("max_wait_us".to_string(), Json::Num(max_wait_us as f64));
                    cell.insert("rate_rps".to_string(), Json::Num(rate));
                    if let Json::Obj(rep) = r.to_json() {
                        cell.extend(rep);
                    }
                    cells.push(Json::Obj(cell));
                }
            }
        }
    }

    let out_dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&out_dir).expect("mkdir results");
    let json = Json::obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("fast", Json::Bool(fast)),
        ("threads", Json::Num(dynadiag::kernels::pool::num_threads() as f64)),
        ("p99_bound_ms", Json::Num(p99_bound_ms)),
        ("cells", Json::Arr(cells)),
    ]);
    let path = out_dir.join("serve_bench.json");
    std::fs::write(&path, json.to_string()).expect("write serve_bench.json");
    println!("\nwrote {}", path.display());

    // -- gates 2 + 3 -----------------------------------------------------
    if alloc_failed {
        eprintln!("FAIL: a measured serving window performed fresh workspace allocations");
        std::process::exit(1);
    }
    if p99_failed {
        eprintln!("FAIL: a cell exceeded the p99 ceiling of {} ms", p99_bound_ms);
        std::process::exit(1);
    }
    println!("PASS: parity bitwise, zero steady-state allocations, p99 under {} ms", p99_bound_ms);
}
