//! `cargo bench --bench serve` — the online-serving load sweep + gates.
//!
//! Sweeps request rate × micro-batch ceiling × sparsity over the serving
//! engine (synthetic finalized models, closed-loop warmup before every
//! measured cell), printing a table and writing
//! `results/serve_bench.json`. `BENCH_serve.json` at the repo root is the
//! committed schema/baseline snapshot.
//!
//! Three gates make this a CI check (`serve-smoke`), not just a report:
//!
//! 1. **Parity** — batched serving output must be *bitwise* identical to
//!    sequential single-request inference for the same requests (the
//!    micro-batcher must be invisible). Mismatch exits 1.
//! 2. **Steady-state allocations** — the measured window of every cell
//!    must perform zero fresh workspace allocations (the arena contract).
//!    Violation exits 1.
//! 3. **p99 ceiling** — every cell's p99 must stay under
//!    `DYNADIAG_SERVE_P99_MS` (default 250 ms — generous, catches
//!    order-of-magnitude regressions without flaking on shared runners).
//! 4. **Clean counters** — no cell in this sweep injects faults or sets
//!    deadlines, so every shed/timeout/failure/restart counter must be
//!    exactly zero. Anything else means the robustness layer is
//!    misfiring on the happy path. Violation exits 1.
//! 5. **Journaled cell** — one sharded cell runs with the request journal
//!    attached and gates both `fresh_allocs == 0` (journaling must not
//!    break the arena contract) and `receipts > 0`.
//! 6. **Wire sweep** — loopback TCP clients drive the network front door
//!    (binary + JSON codecs, closed and open loop) and gate: zero fresh
//!    allocations per warm connection in the measured window, wire p99
//!    within `DYNADIAG_WIRE_P99_FACTOR` (default 1.5x) of the in-process
//!    p99 at matched concurrency, and ledger conservation
//!    (`submitted == served + shed + timed_out + failed`) through a
//!    mid-load client disconnect and a mid-load drain trigger.
//! 7. **Trace overhead** — an identical sharded cell rerun with the
//!    full-rate span tracer attached must keep p99 within
//!    `DYNADIAG_TRACE_P99_FACTOR` (default 1.15x, + 0.25 ms absolute
//!    slack against scheduler noise) of the untraced window, export one
//!    span per request with zero ring drops, and keep the zero-alloc
//!    steady state.
//! 8. **Scrape** — in-band stats frames and an HTTP GET against
//!    `--metrics-addr` must all succeed under live wire load, carry the
//!    conservation counters, and be counted by the server's wire ledger.
//!
//! Set `DYNADIAG_BENCH_FAST=1` (CI does) for a trimmed sweep with the
//! same JSON schema.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dynadiag::obs::TraceExporter;
use dynadiag::runtime::infer::{mlp_config, DiagModel};
use dynadiag::runtime::native::workspace;
use dynadiag::serve::{
    drive_load, drive_load_sharded, run_client, scrape_metrics, BatchPolicy, ClientReport,
    ClientSpec, Completion, Journal, LoadSpec, ManualClock, NetOptions, NetReport, NetServer,
    ServeEngine, ShardCompletion, ShardPolicy, ShardedServer, Submit,
};
use dynadiag::util::json::Json;
use dynadiag::util::rng::Rng;

/// Batched-vs-sequential parity over one (sparsity, ceiling) point:
/// submit `n` requests, flush through the engine at the given ceiling,
/// and compare every completion bitwise against a direct batch-of-1
/// forward of the same sample. Returns the number of mismatched requests.
fn parity_mismatches(sparsity: f64, max_batch: usize, n: usize, seed: u64) -> usize {
    let cfg = mlp_config("mlp_micro").unwrap();
    let model = DiagModel::synth(cfg, sparsity, seed);
    let sl = model.sample_len();
    let classes = model.classes();
    let mut rng = Rng::new(seed ^ 0xbeef);
    let samples: Vec<Vec<f32>> =
        (0..n).map(|_| (0..sl).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();

    // huge deadline: batches form purely by ceiling, remainder via flush
    let mut engine = ServeEngine::new(
        model.clone(),
        BatchPolicy::new(max_batch, u64::MAX / 2).unwrap(),
    );
    let clock = ManualClock::new();
    let mut out: Vec<Completion> = Vec::new();
    for s in &samples {
        engine.submit(workspace::take_copy_f32(s), &clock).unwrap();
        engine.poll(&clock, &mut out).unwrap();
    }
    while engine.queue_len() > 0 {
        engine.flush(&clock, &mut out).unwrap();
    }
    assert_eq!(out.len(), n, "all requests must complete");

    let mut mismatches = 0usize;
    for c in out.drain(..) {
        let want = model.forward_logits(&samples[c.id as usize], 1).unwrap();
        if c.logits != want {
            mismatches += 1;
        }
        workspace::give_f32(want);
        workspace::give_f32(c.logits);
    }
    mismatches
}

/// Sharded parity: every request served through an N-shard server must be
/// bitwise identical to a direct batch-of-1 forward. Returns mismatches.
fn sharded_parity_mismatches(shards: usize, n: usize, seed: u64) -> usize {
    let cfg = mlp_config("mlp_micro").unwrap();
    let model = DiagModel::synth(cfg, 0.9, seed);
    let sl = model.sample_len();
    let mut rng = Rng::new(seed ^ 0xcafe);
    let samples: Vec<Vec<f32>> =
        (0..n).map(|_| (0..sl).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();

    let mut server = ShardedServer::start(
        model.clone(),
        ShardPolicy {
            shards,
            batch: BatchPolicy::new(4, 200).unwrap(),
            max_outstanding: 16,
            ..ShardPolicy::default()
        },
    )
    .unwrap();
    let mut out: Vec<ShardCompletion> = Vec::new();
    let mut mismatches = 0usize;
    let mut submitted = 0usize;
    let mut done = 0usize;
    while done < n {
        while submitted < n && server.outstanding() < 16 {
            let x = workspace::take_copy_f32(&samples[submitted]);
            match server.try_submit((submitted % (2 * shards)) as u64, x).unwrap() {
                Submit::Ok(_) => submitted += 1,
                Submit::Full(x) => {
                    workspace::give_f32(x);
                    break;
                }
                Submit::Shed(..) => unreachable!("no deadline and no faults configured"),
            }
        }
        server.poll_completions(&mut out, Some(Duration::from_millis(50))).unwrap();
        for c in out.drain(..) {
            let want = model.forward_logits(&samples[c.id as usize], 1).unwrap();
            if c.logits != want {
                mismatches += 1;
            }
            workspace::give_f32(want);
            let shard = c.shard;
            server.recycle_logits(shard, c.logits);
            done += 1;
        }
    }
    server.shutdown().unwrap();
    mismatches
}

/// One wire-sweep cell: a 2-shard server behind the TCP front door on an
/// ephemeral loopback port, warmed in-process first (arenas + EWMA seed),
/// driven by `specs.len()` concurrent loopback clients. `stop_after_ms`
/// trips the drain trigger mid-load (the SIGTERM code path); otherwise the
/// trigger fires after every client finished.
fn wire_cell(
    shards: usize,
    reset_after: u64,
    specs: Vec<ClientSpec>,
    stop_after_ms: Option<u64>,
) -> (NetReport, Vec<ClientReport>) {
    let cfg = mlp_config("mlp_micro").unwrap();
    let dm = DiagModel::synth(cfg, 0.9, 8_200 + shards as u64);
    let sample_len = dm.sample_len();
    let cap = (4 * 8 * shards).max(32);
    let mut server = ShardedServer::start(
        dm,
        ShardPolicy {
            shards,
            batch: BatchPolicy::new(8, 200).unwrap(),
            max_outstanding: cap,
            ..ShardPolicy::default()
        },
    )
    .unwrap();
    // warm the shard arenas and the deadline predictor before any client
    // connects, exactly like `serve --listen` does
    let warm = LoadSpec { requests: 2 * cap, rate_rps: 0.0, max_outstanding: cap, seed: 5 };
    drive_load_sharded(&mut server, &warm, 4 * shards, None, None).unwrap();
    server.seed_ewma();
    server.reset_metrics();

    let stop = Arc::new(AtomicBool::new(false));
    let net = NetServer::bind(
        server,
        "127.0.0.1:0",
        NetOptions {
            conn_window: 0,
            drain_on_idle: false,
            shutdown: Some(stop.clone()),
            obey_signals: false,
            reset_after,
            metrics_addr: None,
        },
    )
    .unwrap();
    let addr = net.local_addr().unwrap().to_string();
    let server_h = std::thread::spawn(move || net.run());

    let stopper = stop_after_ms.map(|ms| {
        let stop = stop.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(ms));
            stop.store(true, Ordering::SeqCst);
        })
    });
    let client_hs: Vec<_> = specs
        .into_iter()
        .map(|spec| {
            let addr = addr.clone();
            std::thread::spawn(move || run_client(&addr, sample_len, &spec))
        })
        .collect();
    let clients: Vec<ClientReport> = client_hs
        .into_iter()
        .map(|h| h.join().expect("client thread").expect("wire client run"))
        .collect();
    if let Some(h) = stopper {
        h.join().expect("stopper thread");
    }
    stop.store(true, Ordering::SeqCst);
    let net_report = server_h.join().expect("server thread").expect("wire server run");
    (net_report, clients)
}

fn main() {
    let fast = std::env::var("DYNADIAG_BENCH_FAST")
        .map(|v| !v.is_empty() && v != "0" && v.to_ascii_lowercase() != "false")
        .unwrap_or(false);
    let p99_bound_ms: f64 = std::env::var("DYNADIAG_SERVE_P99_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250.0);

    // -- gate 1: parity --------------------------------------------------
    println!("== serving parity: batched == sequential (bitwise) ==");
    let mut parity_failed = false;
    for &s in &[0.5, 0.9] {
        for &c in &[1usize, 3, 8] {
            let bad = parity_mismatches(s, c, 32, 1000 + (s * 10.0) as u64 + c as u64);
            println!("  sparsity {:.2} ceiling {}: {}", s, c, if bad == 0 { "ok".to_string() } else { format!("{} MISMATCHES", bad) });
            if bad > 0 {
                parity_failed = true;
            }
        }
    }
    if parity_failed {
        eprintln!("FAIL: batched serving diverged from sequential inference");
        std::process::exit(1);
    }

    // -- the sweep -------------------------------------------------------
    let models: &[&str] = if fast { &["mlp_micro"] } else { &["mlp_micro", "mlp_tiny"] };
    let sparsities: &[f64] = if fast { &[0.9] } else { &[0.5, 0.9] };
    let ceilings: &[usize] = if fast { &[1, 8] } else { &[1, 4, 8, 16] };
    let rates: &[f64] = if fast { &[0.0, 4000.0] } else { &[0.0, 1000.0, 4000.0, 16000.0] };
    let requests = if fast { 256 } else { 2048 };
    let max_wait_us: u64 = 200;

    println!("\n== serving sweep: rate x batch ceiling x sparsity{} ==", if fast { " [fast]" } else { "" });
    println!(
        "{:<10} {:>8} {:>7} {:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>6} {:>6}",
        "model", "sparsity", "ceiling", "rate", "thru rps", "p50 ms", "p95 ms", "p99 ms", "mean ms", "batch", "fresh"
    );
    let mut cells: Vec<Json> = Vec::new();
    let mut alloc_failed = false;
    let mut p99_failed = false;
    let mut clean_failed = false;
    for model_name in models {
        let cfg = mlp_config(model_name).unwrap();
        for &s in sparsities {
            for &ceil in ceilings {
                let dm = DiagModel::synth(cfg, s, 7_000 + (s * 100.0) as u64);
                let mut engine =
                    ServeEngine::new(dm, BatchPolicy::new(ceil, max_wait_us).unwrap());
                // warm the arena at the SAME admission cap as the measured
                // windows — the closed loop bursts to the full cap of
                // payload buffers before the first flush, so a smaller
                // warmup cap would leave the measured window allocating
                let cap = (4 * ceil).max(16);
                let warm = LoadSpec {
                    requests: 2 * cap,
                    rate_rps: 0.0,
                    max_outstanding: cap,
                    seed: 5,
                };
                drive_load(&mut engine, &warm).unwrap();
                for &rate in rates {
                    engine.reset_metrics();
                    let spec = LoadSpec {
                        requests,
                        rate_rps: rate,
                        max_outstanding: cap,
                        seed: 11,
                    };
                    let r = drive_load(&mut engine, &spec).unwrap();
                    println!(
                        "{:<10} {:>7.0}% {:>7} {:>9} {:>9.0} {:>9.3} {:>8.3} {:>8.3} {:>8.3} {:>6.2} {:>6}",
                        model_name,
                        s * 100.0,
                        ceil,
                        if rate > 0.0 { format!("{:.0}", rate) } else { "closed".to_string() },
                        r.throughput_rps,
                        r.p50_ms,
                        r.p95_ms,
                        r.p99_ms,
                        r.mean_ms,
                        r.mean_batch,
                        r.fresh_allocs
                    );
                    if r.fresh_allocs > 0 {
                        alloc_failed = true;
                    }
                    if r.p99_ms > p99_bound_ms {
                        p99_failed = true;
                    }
                    if !r.is_clean() {
                        eprintln!("unclean no-fault cell: {}", r.summary());
                        clean_failed = true;
                    }
                    let mut cell = std::collections::BTreeMap::new();
                    cell.insert("model".to_string(), Json::Str(model_name.to_string()));
                    cell.insert("sparsity".to_string(), Json::Num(s));
                    cell.insert("max_batch".to_string(), Json::Num(ceil as f64));
                    cell.insert("max_wait_us".to_string(), Json::Num(max_wait_us as f64));
                    cell.insert("rate_rps".to_string(), Json::Num(rate));
                    if let Json::Obj(rep) = r.to_json() {
                        cell.extend(rep);
                    }
                    cells.push(Json::Obj(cell));
                }
            }
        }
    }

    // -- shard sweep -----------------------------------------------------
    // The tentpole acceptance axis: N engine shards behind the shared
    // admission queue, closed-loop, per-shard zero-alloc gate, and a
    // throughput gate at 2 shards on multi-core hosts. mlp_tiny gives each
    // request enough arithmetic that the speedup measures compute scaling,
    // not channel overhead.
    println!("\n== shard sweep: closed-loop throughput x shard count ==");
    let shard_counts: &[usize] = if fast { &[1, 2] } else { &[1, 2, 4] };
    // always mlp_tiny: the speedup gate must measure compute scaling, and
    // mlp_micro requests are so cheap that channel overhead would dominate
    // on small CI runners — fast mode trims the request count instead
    let shard_requests = if fast { 384 } else { 2048 };
    let shard_model = "mlp_tiny";
    let shard_ceiling = 8usize;
    let mut shard_cells: Vec<Json> = Vec::new();
    let mut shard_alloc_failed = false;
    let mut thru_by_shards: Vec<(usize, f64)> = Vec::new();
    {
        let cfg = mlp_config(shard_model).unwrap();
        for &n_shards in shard_counts {
            let dm = DiagModel::synth(cfg, 0.9, 8_000 + n_shards as u64);
            let cap = (4 * shard_ceiling * n_shards).max(32);
            let mut server = ShardedServer::start(
                dm,
                ShardPolicy {
                    shards: n_shards,
                    batch: BatchPolicy::new(shard_ceiling, 200).unwrap(),
                    max_outstanding: cap,
                    ..ShardPolicy::default()
                },
            )
            .unwrap();
            let clients = 4 * n_shards;
            let warm = LoadSpec {
                requests: 2 * cap,
                rate_rps: 0.0,
                max_outstanding: cap,
                seed: 5,
            };
            drive_load_sharded(&mut server, &warm, clients, None, None).unwrap();
            server.reset_metrics();
            let spec = LoadSpec {
                requests: shard_requests,
                rate_rps: 0.0,
                max_outstanding: cap,
                seed: 11,
            };
            let r = drive_load_sharded(&mut server, &spec, clients, None, None).unwrap();
            let per_shard = server.shard_stats().unwrap();
            server.shutdown().unwrap();
            let shard_fresh: Vec<usize> = per_shard.iter().map(|s| s.fresh_allocs).collect();
            println!(
                "{:<10} shards {:>2}: {:>9.0} rps, p50 {:>7.3} ms p99 {:>7.3} ms, \
                 mean batch {:.2}, fresh/shard {:?}",
                shard_model, n_shards, r.throughput_rps, r.p50_ms, r.p99_ms, r.mean_batch,
                shard_fresh
            );
            if shard_fresh.iter().any(|&f| f > 0) || r.fresh_allocs > 0 {
                shard_alloc_failed = true;
            }
            if r.p99_ms > p99_bound_ms {
                p99_failed = true;
            }
            if !r.is_clean() {
                eprintln!("unclean no-fault shard cell: {}", r.summary());
                clean_failed = true;
            }
            thru_by_shards.push((n_shards, r.throughput_rps));
            let mut cell = std::collections::BTreeMap::new();
            cell.insert("model".to_string(), Json::Str(shard_model.to_string()));
            cell.insert("sparsity".to_string(), Json::Num(0.9));
            cell.insert("max_batch".to_string(), Json::Num(shard_ceiling as f64));
            cell.insert(
                "fresh_per_shard".to_string(),
                Json::Arr(shard_fresh.iter().map(|&f| Json::Num(f as f64)).collect()),
            );
            if let Json::Obj(rep) = r.to_json() {
                cell.extend(rep);
            }
            shard_cells.push(Json::Obj(cell));
        }
    }
    let speedup_2x = {
        let t1 = thru_by_shards.iter().find(|&&(s, _)| s == 1).map(|&(_, t)| t);
        let t2 = thru_by_shards.iter().find(|&&(s, _)| s == 2).map(|&(_, t)| t);
        match (t1, t2) {
            (Some(t1), Some(t2)) if t1 > 0.0 => Some(t2 / t1),
            _ => None,
        }
    };
    let speedup_min: f64 = std::env::var("DYNADIAG_SHARD_SPEEDUP_MIN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.5);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut shard_speedup_failed = false;
    if let Some(sp) = speedup_2x {
        println!(
            "shard speedup at 2 shards vs 1: {:.2}x (gate {:.2}x, {} cores)",
            sp, speedup_min, cores
        );
        // the gate only makes sense with >= 2 physical cores to scale onto
        if cores >= 2 && dynadiag::kernels::pool::num_threads() >= 2 && sp < speedup_min {
            shard_speedup_failed = true;
        }
    }

    // -- journaled cell --------------------------------------------------
    // One sharded run with the request journal attached: journaling must
    // keep the per-shard zero-alloc steady state AND actually record a
    // receipt per request.
    println!("\n== journaled serving: receipts on, zero-alloc gate ==");
    let mut journal_failed = false;
    let journal_cell = {
        let cfg = mlp_config(shard_model).unwrap();
        let dm = DiagModel::synth(cfg, 0.9, 8_100);
        let n_shards = 2usize;
        let cap = (4 * shard_ceiling * n_shards).max(32);
        let mut server = ShardedServer::start(
            dm,
            ShardPolicy {
                shards: n_shards,
                batch: BatchPolicy::new(shard_ceiling, 200).unwrap(),
                max_outstanding: cap,
                ..ShardPolicy::default()
            },
        )
        .unwrap();
        let jpath = std::env::temp_dir().join(format!(
            "dynadiag_serve_bench_journal_{}.ddjnl",
            std::process::id()
        ));
        // attached before warmup so the journal's scratch encoder reaches
        // its steady-state size alongside the arenas
        server.attach_journal(Journal::create(&jpath).expect("create bench journal"));
        let journal_requests = if fast { 256 } else { 1024 };
        let warm = LoadSpec { requests: 2 * cap, rate_rps: 0.0, max_outstanding: cap, seed: 5 };
        drive_load_sharded(&mut server, &warm, 4 * n_shards, None, None).unwrap();
        server.reset_metrics();
        let spec = LoadSpec {
            requests: journal_requests,
            rate_rps: 0.0,
            max_outstanding: cap,
            seed: 11,
        };
        let r = drive_load_sharded(&mut server, &spec, 4 * n_shards, None, None).unwrap();
        let per_shard = server.shard_stats().unwrap();
        let shard_fresh: Vec<usize> = per_shard.iter().map(|s| s.fresh_allocs).collect();
        let (journal_reqs, receipts) =
            server.take_journal().expect("attached above").finish().expect("finish journal");
        server.shutdown().unwrap();
        let _ = std::fs::remove_file(&jpath);
        println!(
            "{:<10} shards {:>2} [journal]: {:>9.0} rps, {} receipts, fresh/shard {:?}",
            shard_model, n_shards, r.throughput_rps, receipts, shard_fresh
        );
        if shard_fresh.iter().any(|&f| f > 0) || r.fresh_allocs > 0 {
            eprintln!("journaled cell broke the zero-alloc steady state");
            journal_failed = true;
        }
        if receipts == 0 || (receipts as usize) < journal_requests {
            eprintln!(
                "journaled cell recorded {} receipts for {} measured requests",
                receipts, journal_requests
            );
            journal_failed = true;
        }
        if !r.is_clean() {
            eprintln!("unclean journaled cell: {}", r.summary());
            clean_failed = true;
        }
        let mut cell = std::collections::BTreeMap::new();
        cell.insert("model".to_string(), Json::Str(shard_model.to_string()));
        cell.insert("max_batch".to_string(), Json::Num(shard_ceiling as f64));
        cell.insert("journal_requests".to_string(), Json::Num(journal_reqs as f64));
        cell.insert("journal_receipts".to_string(), Json::Num(receipts as f64));
        cell.insert(
            "fresh_per_shard".to_string(),
            Json::Arr(shard_fresh.iter().map(|&f| Json::Num(f as f64)).collect()),
        );
        if let Json::Obj(rep) = r.to_json() {
            cell.extend(rep);
        }
        Json::Obj(cell)
    };

    // -- trace-overhead cell ---------------------------------------------
    // Same server, same offered load, tracing off then on: attaching the
    // fixed-slot span rings + the full-rate JSONL exporter must not move
    // p99 past DYNADIAG_TRACE_P99_FACTOR (default 1.15x, + 0.25 ms
    // absolute slack against scheduler noise on sub-millisecond
    // baselines), must export exactly one span per measured request with
    // zero ring drops, and must keep the zero-alloc steady state.
    println!("\n== trace overhead: full-rate span export on vs off ==");
    let trace_factor: f64 = std::env::var("DYNADIAG_TRACE_P99_FACTOR")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.15);
    let mut trace_failed = false;
    let trace_cell = {
        let cfg = mlp_config(shard_model).unwrap();
        let dm = DiagModel::synth(cfg, 0.9, 8_300);
        let n_shards = 2usize;
        let cap = (4 * shard_ceiling * n_shards).max(32);
        let mut server = ShardedServer::start(
            dm,
            ShardPolicy {
                shards: n_shards,
                batch: BatchPolicy::new(shard_ceiling, 200).unwrap(),
                max_outstanding: cap,
                ..ShardPolicy::default()
            },
        )
        .unwrap();
        let trace_requests = if fast { 256 } else { 1024 };
        let warm = LoadSpec { requests: 2 * cap, rate_rps: 0.0, max_outstanding: cap, seed: 5 };
        drive_load_sharded(&mut server, &warm, 4 * n_shards, None, None).unwrap();
        let spec = LoadSpec {
            requests: trace_requests,
            rate_rps: 0.0,
            max_outstanding: cap,
            seed: 11,
        };
        // window A: tracing off
        server.reset_metrics();
        let off = drive_load_sharded(&mut server, &spec, 4 * n_shards, None, None).unwrap();
        // window B: identical load with the tracer attached at rate 1.0
        // (head-samples every span — the worst-case export volume)
        let tpath = std::env::temp_dir().join(format!(
            "dynadiag_serve_bench_traces_{}.jsonl",
            std::process::id()
        ));
        server.attach_tracer(TraceExporter::create(&tpath, 1.0).expect("create bench tracer"));
        server.reset_metrics();
        let on = drive_load_sharded(&mut server, &spec, 4 * n_shards, None, None).unwrap();
        let per_shard = server.shard_stats().unwrap();
        let shard_fresh: Vec<usize> = per_shard.iter().map(|s| s.fresh_allocs).collect();
        let dropped = server.metrics().traces_dropped.get();
        let (sampled, outliers) =
            server.take_tracer().expect("attached above").finish().expect("finish tracer");
        server.shutdown().unwrap();
        let _ = std::fs::remove_file(&tpath);
        let trace_p99_bound = trace_factor * off.p99_ms + 0.25;
        println!(
            "{:<10} shards {:>2} [trace]: p99 off {:.3} ms / on {:.3} ms \
             (gate {:.2}x + 0.25 ms = {:.3} ms), {} spans exported, {} dropped, fresh/shard {:?}",
            shard_model,
            n_shards,
            off.p99_ms,
            on.p99_ms,
            trace_factor,
            trace_p99_bound,
            sampled + outliers,
            dropped,
            shard_fresh
        );
        if on.p99_ms > trace_p99_bound {
            eprintln!(
                "tracing moved p99 from {:.3} ms to {:.3} ms, past the {:.3} ms overhead gate",
                off.p99_ms, on.p99_ms, trace_p99_bound
            );
            trace_failed = true;
        }
        if shard_fresh.iter().any(|&f| f > 0) || on.fresh_allocs > 0 {
            eprintln!("tracing broke the zero-alloc steady state: fresh/shard {:?}", shard_fresh);
            trace_failed = true;
        }
        if dropped > 0 || (sampled as usize) < trace_requests {
            eprintln!(
                "tracer exported {} spans (+{} outliers) with {} ring drops for {} requests",
                sampled, outliers, dropped, trace_requests
            );
            trace_failed = true;
        }
        if !off.is_clean() || !on.is_clean() {
            eprintln!("unclean trace-overhead cell: {} / {}", off.summary(), on.summary());
            clean_failed = true;
        }
        Json::obj(vec![
            ("measured", Json::Bool(true)),
            ("model", Json::Str(shard_model.to_string())),
            ("shards", Json::Num(n_shards as f64)),
            ("requests", Json::Num(trace_requests as f64)),
            ("sample_rate", Json::Num(1.0)),
            ("p99_gate_factor", Json::Num(trace_factor)),
            ("p99_off_ms", Json::Num(off.p99_ms)),
            ("p99_on_ms", Json::Num(on.p99_ms)),
            (
                "p99_factor",
                if off.p99_ms > 0.0 { Json::Num(on.p99_ms / off.p99_ms) } else { Json::Null },
            ),
            ("throughput_off_rps", Json::Num(off.throughput_rps)),
            ("throughput_on_rps", Json::Num(on.throughput_rps)),
            ("spans_exported", Json::Num((sampled + outliers) as f64)),
            ("spans_dropped", Json::Num(dropped as f64)),
            ("fresh_allocs", Json::Num(on.fresh_allocs as f64)),
            (
                "fresh_per_shard",
                Json::Arr(shard_fresh.iter().map(|&f| Json::Num(f as f64)).collect()),
            ),
        ])
    };

    // sharded parity: bitwise identical to sequential at every shard count
    println!("\n== sharded parity: N-shard serving == sequential (bitwise) ==");
    let mut shard_parity_failed = false;
    for &n_shards in shard_counts {
        let bad = sharded_parity_mismatches(n_shards, 32, 2_000 + n_shards as u64);
        println!(
            "  shards {}: {}",
            n_shards,
            if bad == 0 { "ok".to_string() } else { format!("{} MISMATCHES", bad) }
        );
        if bad > 0 {
            shard_parity_failed = true;
        }
    }

    // -- wire sweep ------------------------------------------------------
    // Loopback TCP clients over the network front door. Gates: zero fresh
    // allocations per warm connection in the measured window, wire p99
    // within a factor of the in-process p99 at matched concurrency, and
    // the whole-run wire ledger conserved through a mid-load client
    // disconnect and a mid-load drain trigger (the SIGTERM path).
    println!("\n== wire sweep: TCP front door over the admission queue ==");
    let wire_factor: f64 = std::env::var("DYNADIAG_WIRE_P99_FACTOR")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.5);
    let wire_shards = 2usize;
    let wire_requests = if fast { 128 } else { 512 };

    // in-process baseline at matched concurrency: 2 clients x window 8
    let in_process_p99_ms = {
        let cfg = mlp_config("mlp_micro").unwrap();
        let dm = DiagModel::synth(cfg, 0.9, 8_200 + wire_shards as u64);
        let cap = (4 * 8 * wire_shards).max(32);
        let mut server = ShardedServer::start(
            dm,
            ShardPolicy {
                shards: wire_shards,
                batch: BatchPolicy::new(8, 200).unwrap(),
                max_outstanding: cap,
                ..ShardPolicy::default()
            },
        )
        .unwrap();
        let warm = LoadSpec { requests: 2 * cap, rate_rps: 0.0, max_outstanding: cap, seed: 5 };
        drive_load_sharded(&mut server, &warm, 4 * wire_shards, None, None).unwrap();
        server.reset_metrics();
        let spec = LoadSpec {
            requests: 2 * wire_requests,
            rate_rps: 0.0,
            max_outstanding: 16,
            seed: 11,
        };
        let r = drive_load_sharded(&mut server, &spec, 2, None, None).unwrap();
        server.shutdown().unwrap();
        r.p99_ms
    };

    let mut wire_cells: Vec<Json> = Vec::new();
    let mut wire_alloc_failed = false;
    let mut wire_conserve_failed = false;
    let mut wire_drain_failed = false;
    let mut wire_p99_ms = 0.0f64;
    let push_wire_cell =
        |name: &str,
         net: &NetReport,
         clients: &[ClientReport],
         cells: &mut Vec<Json>,
         conserve_failed: &mut bool| {
            println!(
                "  {:<18} {:>4} conns {:>6} submitted = {:>6} served + {:>4} shed \
                 + {} to + {} failed, reader_fresh {}, driver fresh {}, p99 {:.3} ms{}",
                name,
                net.wire.connections,
                net.wire.submitted,
                net.wire.served,
                net.wire.shed,
                net.wire.timed_out,
                net.wire.failed,
                net.wire.reader_fresh,
                net.report.fresh_allocs,
                net.report.p99_ms,
                if net.wire.conserved() { "" } else { "  LEDGER IMBALANCE" }
            );
            if !net.wire.conserved() {
                *conserve_failed = true;
            }
            cells.push(Json::obj(vec![
                ("name", Json::Str(name.to_string())),
                ("net", net.to_json()),
                ("clients", Json::Arr(clients.iter().map(|c| c.to_json()).collect())),
            ]));
        };

    // cell 1: closed-loop, binary codec, 2 clients. The measurement
    // window resets once the first half of the traffic warmed the
    // per-connection pools; the second half must be allocation-free.
    {
        let specs = vec![
            ClientSpec { requests: wire_requests, seed: 21, ..ClientSpec::default() },
            ClientSpec { requests: wire_requests, seed: 22, ..ClientSpec::default() },
        ];
        let (net, clients) = wire_cell(wire_shards, wire_requests as u64, specs, None);
        wire_p99_ms = net.report.p99_ms;
        if net.report.fresh_allocs > 0 || net.wire.reader_fresh > 0 {
            eprintln!(
                "warm wire connections allocated: driver fresh {} reader fresh {}",
                net.report.fresh_allocs, net.wire.reader_fresh
            );
            wire_alloc_failed = true;
        }
        push_wire_cell(
            "closed/binary",
            &net,
            &clients,
            &mut wire_cells,
            &mut wire_conserve_failed,
        );
    }
    // cell 2: open-loop (Poisson arrivals), binary codec
    {
        let specs = vec![ClientSpec {
            requests: if fast { 64 } else { 256 },
            rate_rps: if fast { 1500.0 } else { 3000.0 },
            seed: 23,
            ..ClientSpec::default()
        }];
        let (net, clients) = wire_cell(wire_shards, 0, specs, None);
        push_wire_cell(
            "open/binary",
            &net,
            &clients,
            &mut wire_cells,
            &mut wire_conserve_failed,
        );
    }
    // cell 3: the JSON debug codec (conservation only; it allocates per
    // line by design)
    {
        let specs =
            vec![ClientSpec { requests: 48, json: true, seed: 24, ..ClientSpec::default() }];
        let (net, clients) = wire_cell(wire_shards, 0, specs, None);
        push_wire_cell(
            "json",
            &net,
            &clients,
            &mut wire_cells,
            &mut wire_conserve_failed,
        );
    }
    // cell 4: ledger through faults — one client hard-disconnects with
    // requests in flight, another is still submitting when the drain
    // trigger (the SIGTERM code path) fires mid-load
    {
        let specs = vec![
            ClientSpec {
                requests: wire_requests,
                disconnect_after: Some(wire_requests / 2),
                seed: 25,
                ..ClientSpec::default()
            },
            ClientSpec { requests: 100 * wire_requests, seed: 26, ..ClientSpec::default() },
        ];
        let (net, clients) = wire_cell(wire_shards, 0, specs, Some(if fast { 60 } else { 150 }));
        if !net.wire.drained || !net.wire.conserved() {
            eprintln!(
                "disconnect+drain cell: drained={} conserved={}",
                net.wire.drained,
                net.wire.conserved()
            );
            wire_drain_failed = true;
        }
        push_wire_cell(
            "disconnect+drain",
            &net,
            &clients,
            &mut wire_cells,
            &mut wire_conserve_failed,
        );
    }
    // the wire p99 gate carries a small absolute slack so scheduler noise
    // on sub-millisecond baselines cannot flake it
    let wire_p99_bound = wire_factor * in_process_p99_ms + 0.25;
    let wire_p99_failed = wire_p99_ms > wire_p99_bound;
    println!(
        "  wire p99 {:.3} ms vs in-process p99 {:.3} ms (gate {:.1}x + 0.25 ms = {:.3} ms){}",
        wire_p99_ms,
        in_process_p99_ms,
        wire_factor,
        wire_p99_bound,
        if wire_p99_failed { "  FAIL" } else { "" }
    );
    let wire_sweep_json = Json::obj(vec![
        ("measured", Json::Bool(true)),
        ("shards", Json::Num(wire_shards as f64)),
        ("p99_gate_factor", Json::Num(wire_factor)),
        ("in_process_p99_ms", Json::Num(in_process_p99_ms)),
        ("wire_p99_ms", Json::Num(wire_p99_ms)),
        ("cells", Json::Arr(wire_cells)),
    ]);

    // -- scrape cell -----------------------------------------------------
    // The telemetry plane under live wire load: one loopback client keeps
    // the front door busy while the bench issues in-band stats frames on
    // fresh connections and one HTTP GET against the --metrics-addr
    // sidecar listener. Every scrape must succeed, carry the conservation
    // counters, and be counted by the server's wire ledger.
    println!("\n== scrape: stats frames + HTTP exposition under load ==");
    let mut scrape_failed = false;
    let scrape_cell = {
        let cfg = mlp_config("mlp_micro").unwrap();
        let dm = DiagModel::synth(cfg, 0.9, 8_400);
        let sample_len = dm.sample_len();
        let n_shards = 2usize;
        let cap = (4 * 8 * n_shards).max(32);
        let mut server = ShardedServer::start(
            dm,
            ShardPolicy {
                shards: n_shards,
                batch: BatchPolicy::new(8, 200).unwrap(),
                max_outstanding: cap,
                ..ShardPolicy::default()
            },
        )
        .unwrap();
        let warm = LoadSpec { requests: 2 * cap, rate_rps: 0.0, max_outstanding: cap, seed: 5 };
        drive_load_sharded(&mut server, &warm, 4 * n_shards, None, None).unwrap();
        server.seed_ewma();
        server.reset_metrics();
        let stop = Arc::new(AtomicBool::new(false));
        let net = NetServer::bind(
            server,
            "127.0.0.1:0",
            NetOptions {
                conn_window: 0,
                drain_on_idle: false,
                shutdown: Some(stop.clone()),
                obey_signals: false,
                reset_after: 0,
                metrics_addr: Some("127.0.0.1:0".to_string()),
            },
        )
        .unwrap();
        let addr = net.local_addr().unwrap().to_string();
        let maddr = net.metrics_local_addr().expect("metrics listener bound");
        let server_h = std::thread::spawn(move || net.run());

        let scrape_requests = if fast { 128 } else { 256 };
        let spec = ClientSpec { requests: scrape_requests, seed: 31, ..ClientSpec::default() };
        let caddr = addr.clone();
        let client_h = std::thread::spawn(move || run_client(&caddr, sample_len, &spec));

        let n_scrapes = 8usize;
        let mut scrape_us: Vec<u64> = Vec::new();
        let mut exposition_bytes = 0usize;
        for _ in 0..n_scrapes {
            // ddlint: allow(clock) -- bench measures real scrape latency
            let t0 = std::time::Instant::now();
            match scrape_metrics(&addr) {
                Ok(text) => {
                    scrape_us.push(t0.elapsed().as_micros() as u64);
                    exposition_bytes = text.len();
                    if !text.contains("dynadiag_requests_submitted_total")
                        || !text.contains("dynadiag_request_latency_us_count")
                    {
                        eprintln!("scrape exposition is missing conservation counters");
                        scrape_failed = true;
                    }
                }
                Err(e) => {
                    eprintln!("in-band scrape failed: {}", e);
                    scrape_failed = true;
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        let http_ok = (|| -> std::io::Result<bool> {
            use std::io::{Read, Write};
            let mut s = std::net::TcpStream::connect(maddr)?;
            s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n")?;
            let mut buf = String::new();
            s.read_to_string(&mut buf)?;
            Ok(buf.starts_with("HTTP/1.0 200 OK\r\n")
                && buf.contains("dynadiag_requests_submitted_total"))
        })()
        .unwrap_or(false);
        if !http_ok {
            eprintln!("HTTP scrape against --metrics-addr failed");
            scrape_failed = true;
        }

        let clients = [client_h.join().expect("client thread").expect("scrape-cell client")];
        stop.store(true, Ordering::SeqCst);
        let net_report = server_h.join().expect("server thread").expect("scrape-cell server");
        let want_scrapes = (n_scrapes + 1) as u64;
        if net_report.wire.scrapes < want_scrapes {
            eprintln!(
                "server counted {} scrapes, expected at least {}",
                net_report.wire.scrapes, want_scrapes
            );
            scrape_failed = true;
        }
        if !net_report.wire.conserved() {
            eprintln!("scrape cell wire ledger imbalance");
            scrape_failed = true;
        }
        scrape_us.sort_unstable();
        let scrape_p50_us = scrape_us.get(scrape_us.len() / 2).copied().unwrap_or(0);
        let scrape_max_us = scrape_us.last().copied().unwrap_or(0);
        println!(
            "  {} in-band scrapes (p50 {} us, max {} us) + 1 http GET, {} exposition bytes, \
             server counted {}",
            scrape_us.len(),
            scrape_p50_us,
            scrape_max_us,
            exposition_bytes,
            net_report.wire.scrapes
        );
        Json::obj(vec![
            ("measured", Json::Bool(true)),
            ("in_band_scrapes", Json::Num(scrape_us.len() as f64)),
            ("http_scrapes", Json::Num(if http_ok { 1.0 } else { 0.0 })),
            ("scrape_p50_us", Json::Num(scrape_p50_us as f64)),
            ("scrape_max_us", Json::Num(scrape_max_us as f64)),
            ("exposition_bytes", Json::Num(exposition_bytes as f64)),
            ("server_counted_scrapes", Json::Num(net_report.wire.scrapes as f64)),
            ("net", net_report.to_json()),
            ("clients", Json::Arr(clients.iter().map(|c| c.to_json()).collect())),
        ])
    };

    let out_dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&out_dir).expect("mkdir results");
    let json = Json::obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("schema_version", Json::Num(5.0)),
        ("fast", Json::Bool(fast)),
        ("threads", Json::Num(dynadiag::kernels::pool::num_threads() as f64)),
        (
            "isa",
            Json::Str(dynadiag::kernels::microkernel::active().name().to_string()),
        ),
        ("p99_bound_ms", Json::Num(p99_bound_ms)),
        ("cells", Json::Arr(cells)),
        ("shard_sweep", Json::Arr(shard_cells)),
        ("journaled", journal_cell),
        ("wire_sweep", wire_sweep_json),
        ("trace_overhead", trace_cell),
        ("scrape", scrape_cell),
        (
            "shard_speedup_2x",
            speedup_2x.map(Json::Num).unwrap_or(Json::Null),
        ),
    ]);
    let path = out_dir.join("serve_bench.json");
    std::fs::write(&path, json.to_string()).expect("write serve_bench.json");
    println!("\nwrote {}", path.display());

    // -- gates 2..6 ------------------------------------------------------
    if alloc_failed {
        eprintln!("FAIL: a measured serving window performed fresh workspace allocations");
        std::process::exit(1);
    }
    if shard_alloc_failed {
        eprintln!(
            "FAIL: a shard (or the driver) allocated fresh workspace buffers in a measured window"
        );
        std::process::exit(1);
    }
    if p99_failed {
        eprintln!("FAIL: a cell exceeded the p99 ceiling of {} ms", p99_bound_ms);
        std::process::exit(1);
    }
    if shard_parity_failed {
        eprintln!("FAIL: sharded serving diverged from sequential inference");
        std::process::exit(1);
    }
    if shard_speedup_failed {
        eprintln!(
            "FAIL: 2-shard throughput gain below {:.2}x on a {}-core host",
            speedup_min, cores
        );
        std::process::exit(1);
    }
    if clean_failed {
        eprintln!(
            "FAIL: a no-fault cell reported nonzero shed/timeout/failure/restart counters"
        );
        std::process::exit(1);
    }
    if journal_failed {
        eprintln!("FAIL: the journaled cell broke the zero-alloc or receipt contract");
        std::process::exit(1);
    }
    if wire_alloc_failed {
        eprintln!("FAIL: a warm wire connection performed fresh allocations in the measured window");
        std::process::exit(1);
    }
    if wire_conserve_failed {
        eprintln!("FAIL: the wire ledger did not balance (submitted != served + shed + timed_out + failed)");
        std::process::exit(1);
    }
    if wire_p99_failed {
        eprintln!(
            "FAIL: wire p99 {:.3} ms exceeded {:.1}x the in-process p99 {:.3} ms",
            wire_p99_ms, wire_factor, in_process_p99_ms
        );
        std::process::exit(1);
    }
    if wire_drain_failed {
        eprintln!("FAIL: the disconnect+drain cell lost receipts or did not drain gracefully");
        std::process::exit(1);
    }
    if trace_failed {
        eprintln!(
            "FAIL: the trace-overhead cell broke the p99, span-export, or zero-alloc contract"
        );
        std::process::exit(1);
    }
    if scrape_failed {
        eprintln!("FAIL: a metrics scrape failed, was miscounted, or unbalanced the wire ledger");
        std::process::exit(1);
    }
    println!(
        "PASS: parity bitwise (single + sharded), zero steady-state allocations per shard \
         (journaling and tracing included), clean counters on the no-fault sweep, p99 under \
         {} ms, wire ledger conserved with warm connections allocation-free, trace overhead \
         within {:.2}x, telemetry scrapes answered under load",
        p99_bound_ms, trace_factor
    );
}
