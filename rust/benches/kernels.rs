//! `cargo bench --bench kernels` — L3 hot-path microbenchmarks.
//!
//! Times the coordinator-side primitives that sit on the per-step path
//! (mask serialization, soft-topk, prune/grow scoring) and the SpMM
//! implementations backing Figs 4/7 (diag-direct, BCSR, CSR, dense) at the
//! paper's 768×768 layer shape. These are the numbers the §Perf pass in
//! EXPERIMENTS.md iterates on.

use dynadiag::bcsr::convert::diag_to_bcsr;
use dynadiag::bcsr::Csr;
use dynadiag::sparsity::diagonal::{diag_count, DiagMatrix};
use dynadiag::sparsity::mask::Mask;
use dynadiag::sparsity::topk::soft_topk;
use dynadiag::tensor::Tensor;
use dynadiag::util::rng::Rng;
use dynadiag::util::timer::bench;

fn random_diag(rng: &mut Rng, n: usize, k: usize) -> DiagMatrix {
    let offsets = rng.choose_k(n, k);
    let mut d = DiagMatrix::new(n, n, offsets);
    for j in 0..d.k() {
        for i in 0..n {
            d.values[j][i] = rng.normal_f32(0.0, 1.0);
        }
    }
    d
}

/// Clustered offsets — the post-training distribution (ℓ1 + the Apdx D
/// proximity objective concentrate the selected band); random offsets are
/// the worst case where K diagonals light up every block column.
fn clustered_diag(rng: &mut Rng, n: usize, k: usize) -> DiagMatrix {
    let base = rng.below(n);
    let offsets: Vec<usize> = (0..k).map(|j| (base + j + j / 8) % n).collect();
    let mut uniq = offsets.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let mut d = DiagMatrix::new(n, n, uniq);
    for j in 0..d.k() {
        for i in 0..n {
            d.values[j][i] = rng.normal_f32(0.0, 1.0);
        }
    }
    d
}

fn main() {
    let mut rng = Rng::new(2024);
    let n = 768;
    let b = 32;
    let s = 0.9;
    let k = diag_count(n, s);
    let d = random_diag(&mut rng, n, k);
    let dc = clustered_diag(&mut rng, n, k);
    let x = Tensor::randn(&[b, n], 1.0, &mut rng);
    let dense = d.to_dense();
    let csr = Csr::from_dense(&dense);
    let conv = diag_to_bcsr(&d, 32, 0.4).unwrap();
    let conv_c = diag_to_bcsr(&dc, 32, 0.4).unwrap();

    println!("== SpMM at n={} S={:.0}% (K={} diagonals), b={} ==", n, s * 100.0, k, b);
    let t = bench(2, 10, || dense.matmul_t(&x).unwrap());
    println!("dense matmul_t      {:>9.2} ms", t.mean_ms());
    let t = bench(2, 10, || d.matmul_t(&x).unwrap());
    println!("diag direct         {:>9.2} ms", t.mean_ms());
    let t = bench(2, 10, || conv.bcsr.matmul_t(&x).unwrap());
    println!(
        "bcsr random offs    {:>9.2} ms  (nnzb {}, block density {:.2})",
        t.mean_ms(),
        conv.bcsr.nnzb(),
        conv.bcsr.block_density()
    );
    let t = bench(2, 10, || conv_c.bcsr.matmul_t(&x).unwrap());
    println!(
        "bcsr clustered offs {:>9.2} ms  (nnzb {}, block density {:.2})",
        t.mean_ms(),
        conv_c.bcsr.nnzb(),
        conv_c.bcsr.block_density()
    );
    let t = bench(2, 10, || csr.matmul_t(&x).unwrap());
    println!("csr                 {:>9.2} ms", t.mean_ms());
    let t = bench(2, 10, || diag_to_bcsr(&d, 32, 0.4).unwrap());
    println!("diag->bcsr convert  {:>9.2} ms", t.mean_ms());
    let t = bench(2, 10, || d.matmul(&x).unwrap());
    println!("diag transposed     {:>9.2} ms", t.mean_ms());

    println!("\n== coordinator per-step primitives ==");
    let mask = Mask::random(768, 768, k * n, &mut rng);
    let t = bench(2, 20, || mask.to_f32());
    println!("mask -> f32 upload buffer (768^2)  {:>9.3} ms", t.mean_ms());
    let alpha: Vec<f32> = (0..768).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t = bench(2, 50, || soft_topk(&alpha, k as f64, 0.05));
    println!("soft_topk host mirror (D=768)      {:>9.3} ms", t.mean_ms());
    let w = Tensor::randn(&[768, 768], 1.0, &mut rng);
    let t = bench(1, 5, || dynadiag::dst::active_by_magnitude(&mask, &w));
    println!("prune scoring (sort active 768^2)  {:>9.3} ms", t.mean_ms());
    let t = bench(1, 3, || dynadiag::dst::cht::ch3_scores(&mask));
    println!("CHT CH3 link scores (768^2)        {:>9.3} ms", t.mean_ms());
}
