//! `cargo bench --bench kernels` — native kernel sweep + coordinator
//! per-step primitives.
//!
//! Sweeps (dim × sparsity × batch) over the three matmul backends of the
//! `kernels` subsystem — cache-blocked dense GEMM, offset-major diagonal
//! SpMM, and BCSR SpMM — printing a table and writing
//! `results/kernel_bench.json`, which `dynadiag experiment fig7` folds into
//! its report. The headline check: diagonal SpMM beats dense GEMM at ≥90%
//! sparsity.

use dynadiag::bcsr::convert::diag_to_bcsr;
use dynadiag::kernels::{bcsr, dense, DiagPacked};
use dynadiag::sparsity::diagonal::{diag_count, DiagMatrix};
use dynadiag::sparsity::mask::Mask;
use dynadiag::sparsity::topk::soft_topk;
use dynadiag::tensor::Tensor;
use dynadiag::util::json::Json;
use dynadiag::util::rng::Rng;
use dynadiag::util::timer::bench;

fn random_diag(rng: &mut Rng, n: usize, k: usize) -> DiagMatrix {
    let offsets = rng.choose_k(n, k);
    let mut d = DiagMatrix::new(n, n, offsets);
    for j in 0..d.k() {
        for i in 0..n {
            d.values[j][i] = rng.normal_f32(0.0, 1.0);
        }
    }
    d
}

const DIMS: [usize; 2] = [256, 768];
const BATCHES: [usize; 3] = [8, 32, 128];
const SPARSITIES: [f64; 5] = [0.99, 0.95, 0.90, 0.80, 0.50];

fn main() {
    let mut rng = Rng::new(2024);
    let mut cells: Vec<Json> = Vec::new();
    let mut best_90: Option<(usize, usize, f64)> = None;

    println!("== native kernel sweep: dense vs diag vs bcsr (y = x @ W.T) ==");
    println!(
        "{:>5} {:>6} {:>9} {:>5} {:>10} {:>10} {:>10} {:>9}",
        "dim", "batch", "sparsity", "K", "dense ms", "diag ms", "bcsr ms", "diag spd"
    );
    for &n in &DIMS {
        for &b in &BATCHES {
            let x: Vec<f32> = (0..b * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> = (0..n * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y = vec![0.0f32; b * n];
            let t_dense = bench(1, 5, || dense::gemm_t(&x, &w, &mut y, b, n, n));
            for &s in &SPARSITIES {
                let k = diag_count(n, s);
                let d = random_diag(&mut rng, n, k);
                let packed = DiagPacked::from_matrix(&d);
                let mut yd = vec![0.0f32; b * n];
                let t_diag = bench(1, 5, || {
                    dynadiag::kernels::diag::spmm_t(
                        &x, &packed.offsets, &packed.values, &mut yd, b, n, n,
                    )
                });
                let conv = diag_to_bcsr(&d, 32, 0.4).expect("bcsr conversion");
                let mut yb = vec![0.0f32; b * n];
                let t_bcsr = bench(1, 5, || {
                    bcsr::spmm_t(
                        &x,
                        &conv.bcsr.row_ptr,
                        &conv.bcsr.col_idx,
                        &conv.bcsr.blocks,
                        conv.bcsr.bs,
                        n,
                        n,
                        &mut yb,
                        b,
                    )
                });
                let speedup = t_dense.mean_s / t_diag.mean_s;
                if s >= 0.90 && speedup > best_90.map(|(_, _, v)| v).unwrap_or(0.0) {
                    best_90 = Some((n, b, speedup));
                }
                println!(
                    "{:>5} {:>6} {:>8.0}% {:>5} {:>10.3} {:>10.3} {:>10.3} {:>8.2}x",
                    n,
                    b,
                    s * 100.0,
                    k,
                    t_dense.mean_ms(),
                    t_diag.mean_ms(),
                    t_bcsr.mean_ms(),
                    speedup
                );
                cells.push(Json::obj(vec![
                    ("dim", Json::Num(n as f64)),
                    ("batch", Json::Num(b as f64)),
                    ("sparsity", Json::Num(s)),
                    ("k", Json::Num(k as f64)),
                    ("dense_ms", Json::Num(t_dense.mean_ms())),
                    ("diag_ms", Json::Num(t_diag.mean_ms())),
                    ("bcsr_ms", Json::Num(t_bcsr.mean_ms())),
                    ("diag_speedup", Json::Num(speedup)),
                    ("bcsr_speedup", Json::Num(t_dense.mean_s / t_bcsr.mean_s)),
                ]));
            }
        }
    }

    match best_90 {
        Some((n, b, v)) if v > 1.0 => println!(
            "\ndiag SpMM beats dense GEMM at >=90% sparsity: best {:.2}x at dim {} batch {}",
            v, n, b
        ),
        _ => println!("\nWARNING: diag SpMM did not beat dense at >=90% sparsity on this run"),
    }

    let out_dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&out_dir).expect("mkdir results");
    let json = Json::obj(vec![
        ("bench", Json::Str("kernels".to_string())),
        ("threads", Json::Num(dynadiag::kernels::pool::num_threads() as f64)),
        ("cells", Json::Arr(cells)),
    ]);
    let path = out_dir.join("kernel_bench.json");
    std::fs::write(&path, json.to_string()).expect("write kernel_bench.json");
    println!("wrote {}", path.display());

    println!("\n== coordinator per-step primitives ==");
    let n = 768;
    let k = diag_count(n, 0.9);
    let mask = Mask::random(n, n, k * n, &mut rng);
    let t = bench(2, 20, || mask.to_f32());
    println!("mask -> f32 upload buffer (768^2)  {:>9.3} ms", t.mean_ms());
    let alpha: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t = bench(2, 50, || soft_topk(&alpha, k as f64, 0.05));
    println!("soft_topk host mirror (D=768)      {:>9.3} ms", t.mean_ms());
    let w = Tensor::randn(&[n, n], 1.0, &mut rng);
    let t = bench(1, 5, || dynadiag::dst::active_by_magnitude(&mask, &w));
    println!("prune scoring (sort active 768^2)  {:>9.3} ms", t.mean_ms());
    let t = bench(1, 3, || dynadiag::dst::cht::ch3_scores(&mask));
    println!("CHT CH3 link scores (768^2)        {:>9.3} ms", t.mean_ms());
    let d = random_diag(&mut rng, n, k);
    let t = bench(1, 5, || diag_to_bcsr(&d, 32, 0.4).unwrap());
    println!("diag->bcsr convert (768^2, K={})   {:>9.3} ms", k, t.mean_ms());
}
