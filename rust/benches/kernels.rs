//! `cargo bench --bench kernels` — native kernel sweep + training-step
//! timing + coordinator per-step primitives.
//!
//! Sweeps (dim × sparsity × batch) over the three matmul backends of the
//! `kernels` subsystem — cache-blocked dense GEMM, offset-major diagonal
//! SpMM, and BCSR SpMM — for all three training products (forward,
//! input-grad, weight-grad), printing a table and writing
//! `results/kernel_bench.json`. Every cell records the per-kernel speedup
//! ratios (diag vs dense at equal layer shape) directly, so `dynadiag
//! experiment fig7` consumes them without recomputation. A `train_step`
//! section times the native `mlp_*` train artifacts through the
//! zero-allocation workspace path.
//!
//! The headline check mirrors ISSUE 2's acceptance bar: diagonal `spmm_t`
//! at 90% sparsity must beat `gemm_t` by ≥ 2x at dim ≥ 1024.
//!
//! Set `DYNADIAG_BENCH_FAST=1` (the CI `bench-smoke` job does) for a
//! shortened sweep with the same JSON schema.

use dynadiag::bcsr::convert::diag_to_bcsr;
use dynadiag::kernels::microkernel;
use dynadiag::kernels::{bcsr, dense, diag, DiagPacked};
use dynadiag::runtime::native::drive;
use dynadiag::runtime::{BackendKind, Session};
use dynadiag::sparsity::diagonal::{diag_count, DiagMatrix};
use dynadiag::sparsity::mask::Mask;
use dynadiag::sparsity::topk::soft_topk;
use dynadiag::tensor::Tensor;
use dynadiag::util::json::Json;
use dynadiag::util::rng::Rng;
use dynadiag::util::timer::bench;

fn random_diag(rng: &mut Rng, n: usize, k: usize) -> DiagMatrix {
    let offsets = rng.choose_k(n, k);
    let mut d = DiagMatrix::new(n, n, offsets);
    for j in 0..d.k() {
        for i in 0..n {
            d.values[j][i] = rng.normal_f32(0.0, 1.0);
        }
    }
    d
}

/// Drive a native train artifact like the trainer does (outputs fed back,
/// buffers recycled through the workspace) and return per-step stats.
/// The input synthesis + feedback routing is the same `drive` helper the
/// steady-state allocation test uses.
fn bench_train_step(name: &str, iters: usize) -> Option<dynadiag::util::timer::BenchStats> {
    let session = Session::open_kind(BackendKind::Native, "artifacts").ok()?;
    let art = session.executable(name).ok()?;
    let mut inputs = drive::synth_train_inputs(&art, 404);
    let mut feedback = drive::TrainFeedback::new(&art);
    let stats = bench(2, iters, || {
        let outputs = art.run(&inputs).unwrap();
        feedback.apply(&mut inputs, outputs);
    });
    Some(stats)
}

fn main() {
    // fast mode iff the var is set to something truthy (a literal "0" or
    // empty string must NOT silently trim the sweep)
    let fast = std::env::var("DYNADIAG_BENCH_FAST")
        .map(|v| !v.is_empty() && v != "0" && v.to_ascii_lowercase() != "false")
        .unwrap_or(false);
    let dims: &[usize] = if fast { &[256, 1024] } else { &[256, 768, 1024] };
    let batches: &[usize] = if fast { &[32] } else { &[8, 32, 128] };
    let sparsities: &[f64] = if fast {
        &[0.90, 0.50]
    } else {
        &[0.99, 0.95, 0.90, 0.80, 0.50]
    };
    let iters = if fast { 3 } else { 5 };

    let mut rng = Rng::new(2024);
    let mut cells: Vec<Json> = Vec::new();
    // acceptance tracker: fwd speedup at S >= 0.90 and dim >= 1024
    let mut best_90_large: Option<(usize, usize, f64)> = None;

    println!(
        "== native kernel sweep: dense vs diag vs bcsr (fwd / input-grad / weight-grad){} ==",
        if fast { " [fast]" } else { "" }
    );
    println!(
        "{:>5} {:>6} {:>9} {:>5} {:>10} {:>10} {:>10} {:>8} {:>8} {:>8}",
        "dim", "batch", "sparsity", "K", "dense ms", "diag ms", "bcsr ms", "fwd spd", "bwd spd", "dW spd"
    );
    for &n in dims {
        for &b in batches {
            let x: Vec<f32> = (0..b * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> = (0..n * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let dy: Vec<f32> = (0..b * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y = vec![0.0f32; b * n];
            let mut dx = vec![0.0f32; b * n];
            let mut dw = vec![0.0f32; n * n];
            let t_dense_fwd = bench(1, iters, || dense::gemm_t(&x, &w, &mut y, b, n, n));
            let t_dense_bwd = bench(1, iters, || dense::gemm(&dy, &w, &mut dx, b, n, n));
            let t_dense_wg = bench(1, iters, || dense::gemm_grad_w(&dy, &x, &mut dw, b, n, n));
            for &s in sparsities {
                let k = diag_count(n, s);
                let d = random_diag(&mut rng, n, k);
                let packed = DiagPacked::from_matrix(&d);
                let mut yd = vec![0.0f32; b * n];
                let mut dxd = vec![0.0f32; b * n];
                let mut dv = vec![0.0f32; k * n];
                let t_diag_fwd = bench(1, iters, || {
                    diag::spmm_t(&x, &packed.offsets, &packed.values, &mut yd, b, n, n)
                });
                let t_diag_bwd = bench(1, iters, || {
                    diag::spmm(&dy, &packed.offsets, &packed.values, &mut dxd, b, n, n)
                });
                let t_diag_wg = bench(1, iters, || {
                    diag::grad_values(&x, &dy, &packed.offsets, &mut dv, b, n, n)
                });
                let conv = diag_to_bcsr(&d, 32, 0.4).expect("bcsr conversion");
                let mut yb = vec![0.0f32; b * n];
                let t_bcsr = bench(1, iters, || {
                    bcsr::spmm_t(
                        &x,
                        &conv.bcsr.row_ptr,
                        &conv.bcsr.col_idx,
                        &conv.bcsr.blocks,
                        conv.bcsr.bs,
                        n,
                        n,
                        &mut yb,
                        b,
                    )
                });
                let fwd_speedup = t_dense_fwd.mean_s / t_diag_fwd.mean_s;
                let bwd_speedup = t_dense_bwd.mean_s / t_diag_bwd.mean_s;
                let wgrad_speedup = t_dense_wg.mean_s / t_diag_wg.mean_s;
                if s >= 0.90
                    && n >= 1024
                    && fwd_speedup > best_90_large.map(|(_, _, v)| v).unwrap_or(0.0)
                {
                    best_90_large = Some((n, b, fwd_speedup));
                }
                println!(
                    "{:>5} {:>6} {:>8.0}% {:>5} {:>10.3} {:>10.3} {:>10.3} {:>7.2}x {:>7.2}x {:>7.2}x",
                    n,
                    b,
                    s * 100.0,
                    k,
                    t_dense_fwd.mean_ms(),
                    t_diag_fwd.mean_ms(),
                    t_bcsr.mean_ms(),
                    fwd_speedup,
                    bwd_speedup,
                    wgrad_speedup
                );
                cells.push(Json::obj(vec![
                    ("dim", Json::Num(n as f64)),
                    ("batch", Json::Num(b as f64)),
                    ("sparsity", Json::Num(s)),
                    ("k", Json::Num(k as f64)),
                    ("dense_ms", Json::Num(t_dense_fwd.mean_ms())),
                    ("diag_ms", Json::Num(t_diag_fwd.mean_ms())),
                    ("bcsr_ms", Json::Num(t_bcsr.mean_ms())),
                    ("diag_speedup", Json::Num(fwd_speedup)),
                    ("bcsr_speedup", Json::Num(t_dense_fwd.mean_s / t_bcsr.mean_s)),
                    ("bwd_dense_ms", Json::Num(t_dense_bwd.mean_ms())),
                    ("bwd_diag_ms", Json::Num(t_diag_bwd.mean_ms())),
                    ("bwd_speedup", Json::Num(bwd_speedup)),
                    ("wgrad_dense_ms", Json::Num(t_dense_wg.mean_ms())),
                    ("wgrad_diag_ms", Json::Num(t_diag_wg.mean_ms())),
                    ("wgrad_speedup", Json::Num(wgrad_speedup)),
                ]));
            }
        }
    }

    match best_90_large {
        Some((n, b, v)) if v >= 2.0 => println!(
            "\nPASS: diag spmm_t >= 2x over gemm_t at >=90% sparsity, dim {} batch {} ({:.2}x)",
            n, b, v
        ),
        Some((n, b, v)) => println!(
            "\nWARNING: best diag spmm_t speedup at >=90% sparsity, dim>=1024 is {:.2}x \
             (dim {} batch {}) — below the 2x bar (noisy machine?)",
            v, n, b
        ),
        None => println!("\n(no dim >= 1024 cells in this sweep)"),
    }

    // per-ISA microkernel cells (ISSUE 6): the ROADMAP shape (dim 1024,
    // batch 32, s=0.90) timed on every ISA path this host can execute, via
    // the explicit `*_on` entries — so one run on an AVX2 or NEON host
    // reports both the dispatched path and the scalar oracle it must beat.
    // The scalar oracle pays libm `fmaf` on builds without compiled FMA
    // (the bit-identity contract's deliberate cost), which is why it is
    // kept out of the main sweep above.
    println!(
        "\n== diag microkernel per-ISA cells (dim 1024, batch 32, s=0.90; dispatched: {}) ==",
        microkernel::active().name()
    );
    let mut isa_cells: Vec<Json> = Vec::new();
    {
        let n = 1024usize;
        let b = 32usize;
        let k = diag_count(n, 0.90);
        let d = random_diag(&mut rng, n, k);
        let packed = DiagPacked::from_matrix(&d);
        let x: Vec<f32> = (0..b * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let dy: Vec<f32> = (0..b * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut yd = vec![0.0f32; b * n];
        let mut dxd = vec![0.0f32; b * n];
        let mut dv = vec![0.0f32; k * n];
        let isa_iters = if fast { 3 } else { 8 };
        println!(
            "{:>8} {:>6} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "isa", "lanes", "fwd ms", "bwd ms", "wgrad ms", "fused ms", "vs scalar"
        );
        // scalar is always first in `available()`, so the oracle times are
        // in hand before any vector path needs its ratios
        let mut scalar: Option<(f64, f64, f64, f64)> = None;
        for &isa in microkernel::available() {
            let t_fwd = bench(1, isa_iters, || {
                diag::spmm_t_on(isa, &x, &packed.offsets, &packed.values, &mut yd, b, n, n)
            });
            let t_bwd = bench(1, isa_iters, || {
                diag::spmm_on(isa, &dy, &packed.offsets, &packed.values, &mut dxd, b, n, n)
            });
            let t_wg = bench(1, isa_iters, || {
                diag::grad_values_on(isa, &x, &dy, &packed.offsets, &mut dv, b, n, n)
            });
            let t_fused = bench(1, isa_iters, || {
                diag::spmm_t_bias_on(
                    isa,
                    &x,
                    &packed.offsets,
                    &packed.values,
                    &bias,
                    &mut yd,
                    b,
                    n,
                    n,
                    diag::Epilogue::Gelu,
                )
            });
            let ms = (t_fwd.mean_ms(), t_bwd.mean_ms(), t_wg.mean_ms(), t_fused.mean_ms());
            let base = *scalar.get_or_insert(ms);
            let fwd_vs_scalar = base.0 / ms.0;
            println!(
                "{:>8} {:>6} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>9.2}x",
                isa.name(),
                isa.lanes(),
                ms.0,
                ms.1,
                ms.2,
                ms.3,
                fwd_vs_scalar
            );
            isa_cells.push(Json::obj(vec![
                ("isa", Json::Str(isa.name().to_string())),
                ("lanes", Json::Num(isa.lanes() as f64)),
                ("fwd_ms", Json::Num(ms.0)),
                ("bwd_ms", Json::Num(ms.1)),
                ("wgrad_ms", Json::Num(ms.2)),
                ("fused_ms", Json::Num(ms.3)),
                ("fwd_vs_scalar", Json::Num(fwd_vs_scalar)),
                ("bwd_vs_scalar", Json::Num(base.1 / ms.1)),
                ("wgrad_vs_scalar", Json::Num(base.2 / ms.2)),
                ("fused_vs_scalar", Json::Num(base.3 / ms.3)),
            ]));
        }
    }

    // training-step timing through the zero-allocation native path
    println!("\n== native train-step timing (workspace-recycled loop) ==");
    let mut train_steps: Vec<Json> = Vec::new();
    let models: &[&str] = if fast {
        &["mlp_micro_masked_train"]
    } else {
        &["mlp_micro_masked_train", "mlp_tiny_masked_train", "mlp_micro_dynadiag_train"]
    };
    for name in models {
        match bench_train_step(name, if fast { 5 } else { 20 }) {
            Some(t) => {
                println!(
                    "{:<28} mean {:>8.3} ms  min {:>8.3} ms  ({} steps)",
                    name,
                    t.mean_ms(),
                    t.min_s * 1e3,
                    t.iters
                );
                train_steps.push(Json::obj(vec![
                    ("model", Json::Str(name.to_string())),
                    ("mean_ms", Json::Num(t.mean_ms())),
                    ("min_ms", Json::Num(t.min_s * 1e3)),
                    ("steps", Json::Num(t.iters as f64)),
                ]));
            }
            None => println!("{:<28} unavailable", name),
        }
    }

    let out_dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&out_dir).expect("mkdir results");
    let json = Json::obj(vec![
        ("bench", Json::Str("kernels".to_string())),
        ("fast", Json::Bool(fast)),
        ("threads", Json::Num(dynadiag::kernels::pool::num_threads() as f64)),
        ("isa", Json::Str(microkernel::active().name().to_string())),
        ("cells", Json::Arr(cells)),
        ("isa_cells", Json::Arr(isa_cells)),
        ("train_steps", Json::Arr(train_steps)),
    ]);
    let path = out_dir.join("kernel_bench.json");
    std::fs::write(&path, json.to_string()).expect("write kernel_bench.json");
    println!("wrote {}", path.display());

    if !fast {
        println!("\n== coordinator per-step primitives ==");
        let n = 768;
        let k = diag_count(n, 0.9);
        let mask = Mask::random(n, n, k * n, &mut rng);
        let t = bench(2, 20, || mask.to_f32());
        println!("mask -> f32 upload buffer (768^2)  {:>9.3} ms", t.mean_ms());
        let alpha: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = bench(2, 50, || soft_topk(&alpha, k as f64, 0.05));
        println!("soft_topk host mirror (D=768)      {:>9.3} ms", t.mean_ms());
        let w = Tensor::randn(&[n, n], 1.0, &mut rng);
        let t = bench(1, 5, || dynadiag::dst::active_by_magnitude(&mask, &w));
        println!("prune scoring (sort active 768^2)  {:>9.3} ms", t.mean_ms());
        let t = bench(1, 3, || dynadiag::dst::cht::ch3_scores(&mask));
        println!("CHT CH3 link scores (768^2)        {:>9.3} ms", t.mean_ms());
        let d = random_diag(&mut rng, n, k);
        let t = bench(1, 5, || diag_to_bcsr(&d, 32, 0.4).unwrap());
        println!("diag->bcsr convert (768^2, K={})   {:>9.3} ms", k, t.mean_ms());
    }
}
