//! `cargo bench --bench fig4_timing` — Fig 4: ViT-B inference/training time
//! vs sparsity from the A100 performance model (no training involved).

fn main() {
    let opts = dynadiag::experiments::ExpOpts { steps: None, seeds: 1, fast: true };
    dynadiag::experiments::fig4::run(&opts).unwrap();
}
