//! `cargo bench --bench table2_ppl` — regenerates Table 2 — GPT-mini perplexity matrix.
//!
//! Runs the experiment in its `--fast` profile (fewer steps/batches) so the
//! whole bench suite finishes on one core; `dynadiag experiment table2` runs
//! the full-size version. Cells are cached under results/cells/.

use std::rc::Rc;

fn main() {
    let session = dynadiag::runtime::Session::open("artifacts").expect("make artifacts first");
    let opts = dynadiag::experiments::ExpOpts { steps: None, seeds: 1, fast: true };
    run(&session, &opts).unwrap();
}

fn run(
    session: &Rc<dynadiag::runtime::Session>,
    opts: &dynadiag::experiments::ExpOpts,
) -> anyhow::Result<()> {
    dynadiag::experiments::table2::run(session, opts)
}
