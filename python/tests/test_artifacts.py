"""Artifact-contract tests: IO counts, spec/shape agreement, exec parity.

These catch manifest drift — the Rust coordinator trusts the manifest
blindly, so every artifact's declared inputs/outputs must match what the
traced function actually consumes/produces.
"""

import numpy as np
import pytest

from compile import artifacts as A
from compile import model as M

_NP = {"f32": np.float32, "i32": np.int32}


def _example_inputs(art, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for s in art["inputs"]:
        shp = tuple(s["shape"])
        name = s["name"]
        if s["dtype"] == "i32":
            hi = 8
            if name == "batch/x" or name == "batch/y":
                cfg = art["meta"].get("config", {})
                hi = cfg.get("vocab", cfg.get("classes", 8))
            out.append(rng.integers(0, hi, size=shp).astype(np.int32))
        elif name.startswith("masks/"):
            out.append((rng.random(shp) < 0.5).astype(np.float32))
        elif name == "scalar/step":
            out.append(np.float32(1.0))
        elif name == "scalar/lr":
            out.append(np.float32(1e-3))
        elif name in ("scalar/wd", "scalar/l1"):
            out.append(np.float32(0.0))
        elif name == "scalar/temp":
            out.append(np.float32(1.0))
        elif name == "kvec":
            out.append(np.full(shp, 4.0, np.float32))
        else:
            out.append((0.05 * rng.normal(size=shp)).astype(np.float32))
    return out


@pytest.mark.parametrize("mode", ["masked", "dynadiag"])
def test_train_artifact_io_contract(mode):
    art = A.build_train("vit_micro", mode)
    ins = _example_inputs(art)
    outs = art["fn"](*ins)
    assert len(outs) == len(art["output_names"])
    assert np.isfinite(float(outs[-2])), "loss must be finite"
    # params' shapes mirror params
    n_p = sum(1 for s in art["inputs"] if s["name"].startswith("params/"))
    for i in range(n_p):
        assert outs[i].shape == tuple(art["inputs"][i]["shape"])


def test_train_step_actually_updates_params():
    art = A.build_train("vit_micro", "masked")
    ins = _example_inputs(art)
    outs = art["fn"](*ins)
    moved = 0
    n_p = sum(1 for s in art["inputs"] if s["name"].startswith("params/"))
    for i in range(n_p):
        if not np.allclose(np.asarray(outs[i]), ins[i]):
            moved += 1
    assert moved > n_p // 2, "most params should move after one Adam step"


def test_gradprobe_outputs_dense_grads():
    art = A.build_gradprobe("vit_micro")
    ins = _example_inputs(art)
    outs = art["fn"](*ins)
    assert len(outs) == len(art["output_names"])
    # grads w.r.t. W_eff are dense: nonzero even where mask == 0
    cfg = M.CONFIGS["vit_micro"]
    sparse = sorted(n for n, _, _ in M.sparse_layer_list(cfg))
    mask_in = {s["name"][len("masks/"):]: ins[i]
               for i, s in enumerate(art["inputs"])
               if s["name"].startswith("masks/")}
    g0 = np.asarray(outs[0])
    m0 = mask_in[sparse[0]]
    off_mask = np.abs(g0[m0 == 0])
    assert off_mask.size > 0 and off_mask.max() > 0, \
        "grad-probe must see missing-link gradients (RigL contract)"


@pytest.mark.parametrize("mode", ["masked", "dynadiag"])
def test_eval_artifact(mode):
    art = A.build_eval("vit_micro", mode)
    ins = _example_inputs(art)
    loss, loss_vec, preds = art["fn"](*ins)
    b = M.CONFIGS["vit_micro"]["batch"]
    assert loss_vec.shape == (b,) and preds.shape == (b,)
    np.testing.assert_allclose(float(loss), np.asarray(loss_vec).mean(),
                               rtol=1e-5)


def test_eval_gpt_correct_counts_bounded():
    art = A.build_eval("gpt_mini", "masked")
    ins = _example_inputs(art)
    _, _, correct = art["fn"](*ins)
    cfg = M.CONFIGS["gpt_mini"]
    c = np.asarray(correct)
    assert ((c >= 0) & (c <= cfg["seq"])).all()


def test_diag_infer_matches_eval_when_weights_agree():
    """diag_infer (Pallas path) == masked eval when the masked weights are
    exactly the composed diagonals — Table 8's equivalence, in miniature."""
    from compile.kernels import ref
    cfg = M.CONFIGS["vit_micro"]
    sparsity = 0.5
    art_d = A.build_diag_infer("vit_micro", sparsity)
    art_e = A.build_eval("vit_micro", "masked")
    rng = np.random.default_rng(9)

    ins_d = _example_inputs(art_d, seed=9)
    # name -> index maps
    idx_d = {s["name"]: i for i, s in enumerate(art_d["inputs"])}
    idx_e = {s["name"]: i for i, s in enumerate(art_e["inputs"])}
    ins_e = _example_inputs(art_e, seed=9)

    sparse = {n: (o, i) for n, o, i in M.sparse_layer_list(cfg)}
    # copy shared dense params by name; compose sparse weights
    for s in art_e["inputs"]:
        n = s["name"]
        if n in idx_d:
            ins_e[idx_e[n]] = ins_d[idx_d[n]]
    for lname, (o, i) in sparse.items():
        offs = rng.choice(i, size=A.diag_k(i, sparsity),
                          replace=False).astype(np.int32)
        vals = rng.normal(size=(len(offs), o)).astype(np.float32)
        ins_d[idx_d[f"params/{lname}/offsets"]] = offs
        ins_d[idx_d[f"params/{lname}/values"]] = vals
        w = np.asarray(ref.compose_dense(offs, vals, o, i))
        ins_e[idx_e[f"params/{lname}/w"]] = w
        ins_e[idx_e[f"masks/{lname}"]] = np.ones((o, i), np.float32)

    # same batch
    ins_e[idx_e["batch/x"]] = ins_d[idx_d["batch/x"]]
    ins_e[idx_e["batch/y"]] = ins_d[idx_d["batch/y"]]

    loss_d, preds_d = art_d["fn"](*ins_d)
    loss_e, _, preds_e = art_e["fn"](*ins_e)
    np.testing.assert_allclose(float(loss_d), float(loss_e), rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(preds_d), np.asarray(preds_e))


def test_micro_builders():
    art = A.build_micro_diag(32, 4, batch=2)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, 32)).astype(np.float32)
    offs = np.arange(4, dtype=np.int32)
    vals = rng.normal(size=(4, 32)).astype(np.float32)
    (y,) = art["fn"](x, offs, vals)
    from compile.kernels import ref
    np.testing.assert_allclose(y, ref.diag_matmul_ref(x, offs, vals),
                               atol=1e-5)


def test_manifest_names_unique_and_routed():
    for mode in ["masked", "dynadiag"]:
        art = A.build_train("mixer_micro", mode)
        names = [s["name"] for s in art["inputs"]]
        assert len(names) == len(set(names))
        prefixes = ("params/", "opt_m/", "opt_v/", "masks/", "batch/",
                    "scalar/", "kvec")
        assert all(n.startswith(prefixes) for n in names)
