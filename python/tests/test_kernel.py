"""Kernel-vs-oracle correctness — the core L1 signal.

Hypothesis sweeps shapes/K; every Pallas kernel must match its pure-jnp
oracle to float32 tolerance on every draw.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (bcsr_matmul, diag_matmul, diag_matmul_t,
                             hard_topk_mask, soft_topk, straight_through_topk)
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# diag_matmul forward
# ---------------------------------------------------------------------------

@given(st.integers(1, 6), st.sampled_from([4, 8, 12, 16, 24]),
       st.sampled_from([4, 8, 12, 16, 24, 32]), st.data())
def test_diag_matmul_matches_ref(b, n_in, n_out, data):
    k = data.draw(st.integers(1, n_in))
    rng = _rng(data.draw(st.integers(0, 2**31)))
    x = jnp.asarray(rng.normal(size=(b, n_in)).astype(np.float32))
    offs = jnp.asarray(rng.choice(n_in, size=k, replace=False).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(k, n_out)).astype(np.float32))
    got = diag_matmul(x, offs, vals)
    want = ref.diag_matmul_ref(x, offs, vals)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


@given(st.integers(1, 6), st.sampled_from([4, 8, 16]),
       st.sampled_from([4, 8, 16, 32]), st.data())
def test_diag_matmul_t_matches_ref(b, n_in, n_out, data):
    k = data.draw(st.integers(1, n_in))
    rng = _rng(data.draw(st.integers(0, 2**31)))
    offs = jnp.asarray(rng.choice(n_in, size=k, replace=False).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(k, n_out)).astype(np.float32))
    dy = jnp.asarray(rng.normal(size=(b, n_out)).astype(np.float32))
    got = diag_matmul_t(dy, offs, vals, n_in)
    want = ref.diag_matmul_t_ref(dy, offs, vals, n_in)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_diag_matmul_identity():
    """K = n_in diagonals with unit values reproduces a circulant of ones."""
    n = 8
    x = jnp.eye(n, dtype=jnp.float32)
    offs = jnp.arange(n, dtype=jnp.int32)
    vals = jnp.ones((n, n), dtype=jnp.float32)
    y = diag_matmul(x, offs, vals)
    np.testing.assert_allclose(y, np.ones((n, n)), atol=1e-6)


def test_diag_matmul_single_diagonal_is_permuted_scale():
    """One diagonal with offset 0 acts as elementwise scale (square case)."""
    rng = _rng(3)
    n = 16
    x = jnp.asarray(rng.normal(size=(5, n)).astype(np.float32))
    vals = jnp.asarray(rng.normal(size=(1, n)).astype(np.float32))
    y = diag_matmul(x, jnp.zeros((1,), jnp.int32), vals)
    np.testing.assert_allclose(y, x * vals[0][None, :], atol=1e-6)


def test_fwd_then_t_equals_dense_gram():
    """x→y→(transpose) equals x @ (WᵀW): exercises fwd+t composition."""
    rng = _rng(7)
    b, n = 4, 12
    k = 3
    x = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    offs = jnp.asarray(rng.choice(n, size=k, replace=False).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    w = np.asarray(ref.compose_dense(offs, vals, n, n))
    y = diag_matmul(x, offs, vals)
    z = diag_matmul_t(y, offs, vals, n)
    np.testing.assert_allclose(z, np.asarray(x) @ w.T @ w, atol=1e-4)


# ---------------------------------------------------------------------------
# BCSR
# ---------------------------------------------------------------------------

def _random_bcsr(rng, n_out, n_in, bs, density, pad=2):
    nbr, nbc = n_out // bs, n_in // bs
    present = rng.random((nbr, nbc)) < density
    row_ptr, col_idx, blocks = [0], [], []
    for br in range(nbr):
        for bc in range(nbc):
            if present[br, bc]:
                col_idx.append(bc)
                blocks.append(rng.normal(size=(bs, bs)).astype(np.float32))
        row_ptr.append(len(col_idx))
    for _ in range(pad):
        col_idx.append(0)
        blocks.append(np.zeros((bs, bs), np.float32))
    if not blocks:
        blocks.append(np.zeros((bs, bs), np.float32))
        col_idx.append(0)
    return (jnp.asarray(np.array(row_ptr, np.int32)),
            jnp.asarray(np.array(col_idx, np.int32)),
            jnp.asarray(np.stack(blocks)))


@given(st.sampled_from([8, 16, 32]), st.sampled_from([16, 32]),
       st.sampled_from([4, 8]), st.floats(0.1, 0.9), st.data())
def test_bcsr_matmul_matches_ref(n_out, n_in, bs, density, data):
    if n_out % bs or n_in % bs:
        return
    rng = _rng(data.draw(st.integers(0, 2**31)))
    row_ptr, col_idx, blocks = _random_bcsr(rng, n_out, n_in, bs, density)
    x = jnp.asarray(rng.normal(size=(3, n_in)).astype(np.float32))
    got = bcsr_matmul(x, row_ptr, col_idx, blocks, n_out)
    want = ref.bcsr_matmul_ref(x, row_ptr, col_idx, blocks, n_out)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_bcsr_empty_rows():
    """Block rows with no blocks must produce zero output rows."""
    bs, n = 4, 16
    row_ptr = jnp.asarray(np.array([0, 1, 1, 1, 1], np.int32))
    col_idx = jnp.asarray(np.array([2, 0], np.int32))
    blocks = jnp.asarray(np.stack([np.ones((bs, bs), np.float32),
                                   np.zeros((bs, bs), np.float32)]))
    x = jnp.ones((2, n), dtype=jnp.float32)
    y = bcsr_matmul(x, row_ptr, col_idx, blocks, n)
    assert np.allclose(np.asarray(y)[:, bs:], 0.0)
    assert np.allclose(np.asarray(y)[:, :bs], bs)


# ---------------------------------------------------------------------------
# TopK
# ---------------------------------------------------------------------------

@given(st.integers(4, 64), st.floats(0.05, 10.0), st.data())
def test_soft_topk_matches_ref(d, t, data):
    k = data.draw(st.integers(1, d))
    rng = _rng(data.draw(st.integers(0, 2**31)))
    a = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    got = soft_topk(a, float(k), t)
    want = ref.soft_topk_ref(a, float(k), t)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@given(st.integers(4, 64), st.data())
def test_soft_topk_bounded(d, data):
    k = data.draw(st.integers(1, d))
    rng = _rng(data.draw(st.integers(0, 2**31)))
    a = jnp.asarray((10 * rng.normal(size=(d,))).astype(np.float32))
    out = np.asarray(soft_topk(a, float(k), 0.1))
    assert (out >= 0).all() and (out <= 1.0 + 1e-6).all()


def test_soft_topk_temperature_limits():
    """T→0 concentrates mass on the argmax (exploitation); large T spreads
    toward the uniform k/d weighting (exploration) — the Sec 3.2 dial."""
    a = jnp.asarray(np.array([5.0, 4.0, 3.0, 0.0, -1.0, -2.0], np.float32))
    cold = np.asarray(soft_topk(a, 3.0, 0.01))
    assert np.isclose(cold[0], 1.0, atol=1e-3)
    assert np.allclose(cold[3:], 0.0, atol=1e-3)
    hot = np.asarray(soft_topk(a, 3.0, 1e4))
    assert np.allclose(hot, 3.0 / 6.0, atol=1e-3)
    # ordering is preserved at any temperature
    mid = np.asarray(soft_topk(a, 3.0, 1.0))
    assert (np.diff(mid) <= 1e-6).all()


def test_hard_topk_mask_counts():
    a = jnp.asarray(np.array([0.1, 0.9, -0.5, 0.7, 0.2], np.float32))
    m = np.asarray(hard_topk_mask(a, 2))
    assert m.sum() == 2 and m[1] == 1 and m[3] == 1


def test_straight_through_forward_is_hard():
    a = jnp.asarray(np.array([3.0, 2.0, 1.0, 0.0], np.float32))
    out = np.asarray(straight_through_topk(a, 2, 0.5))
    np.testing.assert_allclose(out, [1, 1, 0, 0], atol=1e-6)
