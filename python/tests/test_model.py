"""L2 model checks: shapes, parameterization equivalences, gradient flow."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M
from compile import optim
from compile.kernels import ref, soft_topk


def _batch(cfg, rng):
    if cfg["kind"] == "gpt":
        x = rng.integers(0, cfg["vocab"], size=(cfg["batch"], cfg["seq"]))
        y = rng.integers(0, cfg["vocab"], size=(cfg["batch"], cfg["seq"]))
        return jnp.asarray(x.astype(np.int32)), jnp.asarray(y.astype(np.int32))
    x = rng.normal(size=(cfg["batch"], cfg["tokens"], cfg["patch_dim"]))
    y = rng.integers(0, cfg["classes"], size=(cfg["batch"],))
    return jnp.asarray(x.astype(np.float32)), jnp.asarray(y.astype(np.int32))


def _to_jnp(tree):
    return jax.tree_util.tree_map(jnp.asarray, tree)


def test_forward_shapes_all_models():
    rng = np.random.default_rng(0)
    for name in ["vit_micro", "mixer_micro", "gpt_mini"]:
        cfg = M.CONFIGS[name]
        params = _to_jnp(M.init_params(cfg, "masked"))
        x, y = _batch(cfg, rng)
        ctx = M.MaskedCtx({})
        logits = M.forward(cfg, params, ctx, x)
        if cfg["kind"] == "gpt":
            assert logits.shape == (cfg["batch"], cfg["seq"], cfg["vocab"])
        else:
            assert logits.shape == (cfg["batch"], cfg["classes"])
        assert np.isfinite(np.asarray(logits)).all()


def test_mask_of_ones_is_dense():
    """masked forward with all-ones masks == no-mask forward."""
    rng = np.random.default_rng(1)
    cfg = M.CONFIGS["vit_micro"]
    params = _to_jnp(M.init_params(cfg, "masked"))
    x, _ = _batch(cfg, rng)
    sparse = M.sparse_layer_list(cfg)
    ones = {n: jnp.ones((o, i)) for n, o, i in sparse}
    a = M.forward(cfg, params, M.MaskedCtx({}), x)
    b = M.forward(cfg, params, M.MaskedCtx(ones), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_zero_mask_blocks_information():
    """Fully-zero masks on fc layers must change the output vs dense."""
    rng = np.random.default_rng(2)
    cfg = M.CONFIGS["vit_micro"]
    params = _to_jnp(M.init_params(cfg, "masked"))
    x, _ = _batch(cfg, rng)
    sparse = M.sparse_layer_list(cfg)
    zeros = {n: jnp.zeros((o, i)) for n, o, i in sparse}
    a = M.forward(cfg, params, M.MaskedCtx({}), x)
    b = M.forward(cfg, params, M.MaskedCtx(zeros), x)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_dynadiag_equals_explicit_composition():
    """DynaDiagCtx output == forward with W composed by the oracle."""
    rng = np.random.default_rng(3)
    cfg = M.CONFIGS["vit_micro"]
    params = _to_jnp(M.init_params(cfg, "dynadiag", seed=5))
    x, _ = _batch(cfg, rng)
    sparse = M.sparse_layer_list(cfg)
    names = [n for n, _, _ in sparse]
    kvec = jnp.asarray(np.full(len(sparse), 4.0, np.float32))
    temp = jnp.float32(0.7)
    ctx = M.DynaDiagCtx(names, temp, kvec)
    a = M.forward(cfg, params, ctx, x)

    # explicit: materialize each W via the oracle, drive MaskedCtx override
    override = {}
    for j, (n, o, i) in enumerate(sparse):
        node = params
        for part in n.split("/"):
            node = node[int(part)] if part.isdigit() else node[part]
        at = soft_topk(node["alpha"], kvec[j], temp)
        override[n] = ref.dynadiag_weight_ref(node["v"], at)

    # MaskedCtx.override expects layers keyed by name but reads bias from
    # the node; adapt by building a masked-tree where "w"/"b" exist.
    class Ctx:
        def linear(self, name, p, xx):
            if name in override:
                return xx @ override[name].T + p["b"]
            return xx @ p["w"].T + p["b"]

    b = M.forward(cfg, params, Ctx(), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_gradients_flow_to_alpha_and_v():
    rng = np.random.default_rng(4)
    cfg = M.CONFIGS["vit_micro"]
    params = _to_jnp(M.init_params(cfg, "dynadiag"))
    x, y = _batch(cfg, rng)
    sparse = M.sparse_layer_list(cfg)
    names = [n for n, _, _ in sparse]
    kvec = jnp.asarray(np.full(len(sparse), 8.0, np.float32))

    def loss_fn(p):
        ctx = M.DynaDiagCtx(names, jnp.float32(1.0), kvec)
        logits = M.forward(cfg, p, ctx, x)
        return M.classification_loss(cfg, logits, y)

    grads = jax.grad(loss_fn)(params)
    g = grads["blocks"][0]["fc1"]
    assert float(jnp.abs(g["alpha"]).sum()) > 0.0
    assert float(jnp.abs(g["v"]).sum()) > 0.0


def test_masked_gradient_is_masked():
    """d loss / d W must vanish on pruned coordinates (W ⊙ M chain rule)."""
    rng = np.random.default_rng(5)
    cfg = M.CONFIGS["vit_micro"]
    params = _to_jnp(M.init_params(cfg, "masked"))
    x, y = _batch(cfg, rng)
    sparse = M.sparse_layer_list(cfg)
    masks = {}
    mrng = np.random.default_rng(6)
    for n, o, i in sparse:
        masks[n] = jnp.asarray((mrng.random((o, i)) < 0.3).astype(np.float32))

    def loss_fn(p):
        logits = M.forward(cfg, p, M.MaskedCtx(masks), x)
        return M.classification_loss(cfg, logits, y)

    grads = jax.grad(loss_fn)(params)
    gw = np.asarray(grads["blocks"][0]["fc1"]["w"])
    m = np.asarray(masks["blocks/0/fc1"])
    assert np.allclose(gw * (1 - m), 0.0, atol=1e-8)


def test_causal_masking_in_gpt():
    """Future tokens must not influence past logits."""
    rng = np.random.default_rng(7)
    cfg = M.CONFIGS["gpt_mini"]
    params = _to_jnp(M.init_params(cfg, "masked"))
    x, _ = _batch(cfg, rng)
    x2 = np.asarray(x).copy()
    x2[:, -1] = (x2[:, -1] + 1) % cfg["vocab"]  # perturb only last token
    a = M.forward(cfg, params, M.MaskedCtx({}), x)
    b = M.forward(cfg, params, M.MaskedCtx({}), jnp.asarray(x2))
    np.testing.assert_allclose(np.asarray(a)[:, :-1], np.asarray(b)[:, :-1],
                               atol=1e-5)
    assert not np.allclose(np.asarray(a)[:, -1], np.asarray(b)[:, -1])


def test_adam_decreases_loss():
    rng = np.random.default_rng(8)
    cfg = M.CONFIGS["vit_micro"]
    params = _to_jnp(M.init_params(cfg, "masked"))
    opt = optim.init_state(params)
    x, y = _batch(cfg, rng)

    def loss_fn(p):
        logits = M.forward(cfg, p, M.MaskedCtx({}), x)
        return M.classification_loss(cfg, logits, y)

    l0 = float(loss_fn(params))
    for t in range(1, 6):
        g = jax.grad(loss_fn)(params)
        params, opt = optim.apply(params, g, opt, jnp.float32(t),
                                  jnp.float32(3e-3), jnp.float32(0.0))
    l1 = float(loss_fn(params))
    assert l1 < l0


def test_flatten_roundtrip():
    cfg = M.CONFIGS["mixer_micro"]
    params = M.init_params(cfg, "dynadiag")
    named = M.flatten_named(params)
    names = [n for n, _ in named]
    assert len(names) == len(set(names)), "names must be unique"
    rebuilt = M.unflatten_like(params, [v for _, v in named])
    named2 = M.flatten_named(rebuilt)
    for (n1, v1), (n2, v2) in zip(named, named2):
        assert n1 == n2
        np.testing.assert_array_equal(v1, v2)


def test_sparse_layer_list_matches_params():
    for name in ["vit_micro", "mixer_micro", "gpt_mini"]:
        cfg = M.CONFIGS[name]
        params = M.init_params(cfg, "masked")
        for lname, o, i in M.sparse_layer_list(cfg):
            node = params
            for part in lname.split("/"):
                node = node[int(part)] if part.isdigit() else node[part]
            assert node["w"].shape == (o, i)
