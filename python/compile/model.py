"""L2 — JAX model zoo + train/eval step builders (build time only).

Three architectures from the paper's evaluation, at CPU-trainable scale
(DESIGN.md §2 records the scale substitution):

  * ``vit``    — Vision Transformer (Table 1, pre-patchified input)
  * ``mixer``  — MLP-Mixer          (Table 1)
  * ``gpt``    — GPT-2-style causal LM (Table 2)

Each sparse layer supports three parameterizations:

  * ``masked``   — W_eff = W ⊙ M; M is a runtime input.  Serves every DST
    baseline (RigL/SET/MEST/SRigL/DSB/PixelatedBFly/DiagHeur/CHT): the Rust
    coordinator mutates M between steps.
  * ``dynadiag`` — W_eff = V ⊙ ᾱ[(j−i) mod n_in], ᾱ = min(k·softmax(α/T), 1)
    (Eq. 4–5).  α and V train by gradient; T / k / ℓ1 are runtime scalars.
  * ``diag``     — inference-only execution over the *selected* K diagonals
    via the L1 Pallas kernel :func:`kernels.diag_matmul` — the sparse
    compute path the paper accelerates with CUDA/BCSR.

Everything here is traced once by ``aot.py`` and shipped to Rust as HLO text;
Python never runs at training time.
"""

import jax
import jax.numpy as jnp
import numpy as np

from . import optim
from .kernels import diag_matmul, soft_topk

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

CONFIGS = {
    # name: dict of model hyperparameters (see builders below)
    "vit_tiny": dict(kind="vit", tokens=64, patch_dim=48, dim=128, depth=4,
                     heads=4, mlp=256, classes=100, batch=32, smoothing=0.1),
    "vit_micro": dict(kind="vit", tokens=16, patch_dim=48, dim=64, depth=3,
                      heads=4, mlp=128, classes=10, batch=64, smoothing=0.1),
    "mixer_tiny": dict(kind="mixer", tokens=64, patch_dim=48, dim=128,
                       token_mlp=64, chan_mlp=256, depth=4, classes=100,
                       batch=32, smoothing=0.1),
    "mixer_micro": dict(kind="mixer", tokens=16, patch_dim=48, dim=64,
                        token_mlp=32, chan_mlp=128, depth=3, classes=10,
                        batch=64, smoothing=0.1),
    "gpt_mini": dict(kind="gpt", vocab=256, seq=64, dim=128, depth=4, heads=4,
                     mlp=512, batch=16, smoothing=0.0),
    # E2E driver config (examples/train_gpt_tinycorpus.rs): ~14M params.
    "gpt_e2e": dict(kind="gpt", vocab=256, seq=128, dim=384, depth=8, heads=8,
                    mlp=1536, batch=8, smoothing=0.0),
}


def sparse_layer_list(cfg):
    """Ordered (name, n_out, n_in) of every sparse layer in the model.

    The order here is the contract for ``kvec`` / mask manifest entries —
    the Rust side replicates it from manifest meta.
    """
    out = []
    kind = cfg["kind"]
    for b in range(cfg["depth"]):
        if kind == "vit":
            # footnote 2: MHA *input* projections stay dense in ViTs
            out.append((f"blocks/{b}/attn_proj", cfg["dim"], cfg["dim"]))
            out.append((f"blocks/{b}/fc1", cfg["mlp"], cfg["dim"]))
            out.append((f"blocks/{b}/fc2", cfg["dim"], cfg["mlp"]))
        elif kind == "mixer":
            out.append((f"blocks/{b}/token_fc1", cfg["token_mlp"], cfg["tokens"]))
            out.append((f"blocks/{b}/token_fc2", cfg["tokens"], cfg["token_mlp"]))
            out.append((f"blocks/{b}/chan_fc1", cfg["chan_mlp"], cfg["dim"]))
            out.append((f"blocks/{b}/chan_fc2", cfg["dim"], cfg["chan_mlp"]))
        elif kind == "gpt":
            # footnote 3: both attention and MLP sparse in GPT-2
            out.append((f"blocks/{b}/qkv", 3 * cfg["dim"], cfg["dim"]))
            out.append((f"blocks/{b}/attn_proj", cfg["dim"], cfg["dim"]))
            out.append((f"blocks/{b}/fc1", cfg["mlp"], cfg["dim"]))
            out.append((f"blocks/{b}/fc2", cfg["dim"], cfg["mlp"]))
        else:
            raise ValueError(kind)
    return out


# ---------------------------------------------------------------------------
# Deterministic named flattening (contract shared with rust/src/train/state.rs)
# ---------------------------------------------------------------------------

def flatten_named(tree, prefix=""):
    """Flatten a nested dict/list tree to [(name, leaf)] — sorted dict keys,
    list indices as path components, '/'-joined."""
    if isinstance(tree, dict):
        items = []
        for k in sorted(tree.keys()):
            items += flatten_named(tree[k], f"{prefix}{k}/")
        return items
    if isinstance(tree, (list, tuple)):
        items = []
        for i, v in enumerate(tree):
            items += flatten_named(v, f"{prefix}{i}/")
        return items
    return [(prefix[:-1], tree)]


def unflatten_like(tree, leaves):
    """Inverse of flatten_named given the template ``tree`` (same order)."""
    it = iter(leaves)

    def rec(t):
        if isinstance(t, dict):
            return {k: rec(t[k]) for k in sorted(t.keys())}
        if isinstance(t, (list, tuple)):
            return [rec(v) for v in t]
        return next(it)

    out = rec(tree)
    return out


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _dense_init(rng, n_out, n_in):
    s = float(np.sqrt(2.0 / (n_in + n_out)))
    return rng.normal(0.0, s, size=(n_out, n_in)).astype(np.float32)


def _sparse_layer_params(rng, n_out, n_in, mode):
    if mode == "masked":
        return {"w": _dense_init(rng, n_out, n_in),
                "b": np.zeros((n_out,), np.float32)}
    if mode == "dynadiag":
        # V carries all candidate diagonals in matrix position; alpha gets a
        # small random init so TopK ties break symmetrically.
        return {"v": _dense_init(rng, n_out, n_in),
                "alpha": (0.01 * rng.normal(size=(n_in,))).astype(np.float32),
                "b": np.zeros((n_out,), np.float32)}
    raise ValueError(mode)


def _dense_layer_params(rng, n_out, n_in):
    return {"w": _dense_init(rng, n_out, n_in),
            "b": np.zeros((n_out,), np.float32)}


def _ln_params(dim):
    return {"g": np.ones((dim,), np.float32), "b": np.zeros((dim,), np.float32)}


def init_params(cfg, mode, seed=0):
    """Numpy parameter tree for a model config (shapes contract for Rust)."""
    rng = np.random.default_rng(seed)
    kind = cfg["kind"]
    sparse = {name: (o, i) for name, o, i in sparse_layer_list(cfg)}

    def maybe_sparse(name, n_out, n_in):
        if name in sparse:
            return _sparse_layer_params(rng, n_out, n_in, mode)
        return _dense_layer_params(rng, n_out, n_in)

    p = {}
    if kind in ("vit", "mixer"):
        p["embed"] = _dense_layer_params(rng, cfg["dim"], cfg["patch_dim"])
        p["pos"] = (0.02 * rng.normal(size=(cfg["tokens"], cfg["dim"]))
                    ).astype(np.float32)
        p["head"] = _dense_layer_params(rng, cfg["classes"], cfg["dim"])
        p["ln_f"] = _ln_params(cfg["dim"])
    else:
        p["tok_embed"] = (0.02 * rng.normal(size=(cfg["vocab"], cfg["dim"]))
                          ).astype(np.float32)
        p["pos"] = (0.02 * rng.normal(size=(cfg["seq"], cfg["dim"]))
                    ).astype(np.float32)
        p["head"] = _dense_layer_params(rng, cfg["vocab"], cfg["dim"])
        p["ln_f"] = _ln_params(cfg["dim"])

    blocks = []
    for b in range(cfg["depth"]):
        blk = {}
        if kind == "vit":
            blk["ln1"] = _ln_params(cfg["dim"])
            blk["qkv"] = _dense_layer_params(rng, 3 * cfg["dim"], cfg["dim"])
            blk["attn_proj"] = maybe_sparse(f"blocks/{b}/attn_proj",
                                            cfg["dim"], cfg["dim"])
            blk["ln2"] = _ln_params(cfg["dim"])
            blk["fc1"] = maybe_sparse(f"blocks/{b}/fc1", cfg["mlp"], cfg["dim"])
            blk["fc2"] = maybe_sparse(f"blocks/{b}/fc2", cfg["dim"], cfg["mlp"])
        elif kind == "mixer":
            blk["ln1"] = _ln_params(cfg["dim"])
            blk["token_fc1"] = maybe_sparse(f"blocks/{b}/token_fc1",
                                            cfg["token_mlp"], cfg["tokens"])
            blk["token_fc2"] = maybe_sparse(f"blocks/{b}/token_fc2",
                                            cfg["tokens"], cfg["token_mlp"])
            blk["ln2"] = _ln_params(cfg["dim"])
            blk["chan_fc1"] = maybe_sparse(f"blocks/{b}/chan_fc1",
                                           cfg["chan_mlp"], cfg["dim"])
            blk["chan_fc2"] = maybe_sparse(f"blocks/{b}/chan_fc2",
                                           cfg["dim"], cfg["chan_mlp"])
        else:  # gpt
            blk["ln1"] = _ln_params(cfg["dim"])
            blk["qkv"] = maybe_sparse(f"blocks/{b}/qkv", 3 * cfg["dim"],
                                      cfg["dim"])
            blk["attn_proj"] = maybe_sparse(f"blocks/{b}/attn_proj",
                                            cfg["dim"], cfg["dim"])
            blk["ln2"] = _ln_params(cfg["dim"])
            blk["fc1"] = maybe_sparse(f"blocks/{b}/fc1", cfg["mlp"], cfg["dim"])
            blk["fc2"] = maybe_sparse(f"blocks/{b}/fc2", cfg["dim"], cfg["mlp"])
        blocks.append(blk)
    p["blocks"] = blocks
    return p


# ---------------------------------------------------------------------------
# Sparse-layer execution contexts
# ---------------------------------------------------------------------------

class MaskedCtx:
    """W_eff = W ⊙ M.  ``override`` lets the grad-probe differentiate w.r.t.
    the *effective* weights (RigL needs gradients of missing links too)."""

    def __init__(self, masks, override=None):
        self.masks = masks
        self.override = override or {}

    def linear(self, name, p, x):
        if name in self.override:
            w = self.override[name]
        elif name in self.masks:
            w = p["w"] * self.masks[name]
        else:
            w = p["w"]
        return x @ w.T + p["b"]


class DynaDiagCtx:
    """Eq. 4–5 composition; collects the ℓ1(α) regularizer on the side."""

    def __init__(self, sparse_names, temperature, kvec):
        self.sparse = {n: j for j, n in enumerate(sparse_names)}
        self.t = temperature
        self.kvec = kvec
        self.l1 = 0.0

    def linear(self, name, p, x):
        if name not in self.sparse:
            return x @ p["w"].T + p["b"]
        j = self.sparse[name]
        atilde = soft_topk(p["alpha"], self.kvec[j], self.t)
        n_out, n_in = p["v"].shape
        # IDX[i, c] = (c - i) mod n_in, built from iotas (tiny HLO, no
        # multi-MB literal in the text artifact).
        idx = (jnp.arange(n_in, dtype=jnp.int32)[None, :]
               - jnp.arange(n_out, dtype=jnp.int32)[:, None]) % n_in
        w = p["v"] * atilde[idx]
        self.l1 = self.l1 + jnp.sum(jnp.abs(p["alpha"]))
        return x @ w.T + p["b"]


class DiagExecCtx:
    """Inference over the selected K diagonals via the L1 Pallas kernel."""

    def __init__(self, sparse_names):
        self.sparse = set(sparse_names)

    def linear(self, name, p, x):
        if name not in self.sparse:
            return x @ p["w"].T + p["b"]
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        y = diag_matmul(x2, p["offsets"], p["values"])
        y = y + p["b"]
        return y.reshape(*shape[:-1], y.shape[-1])


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _layer_norm(p, x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * p["g"] + p["b"]


def _dense(p, x):
    return x @ p["w"].T + p["b"]


def _attention(blk, ctx, bidx, x, heads, causal):
    b, t, d = x.shape
    hd = d // heads
    qkv_name = f"blocks/{bidx}/qkv"
    qkv = ctx.linear(qkv_name, blk["qkv"], x)  # dense in ViT, sparse in GPT
    q, k, v = jnp.split(qkv, 3, axis=-1)
    split = lambda z: z.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
    q, k, v = split(q), split(k), split(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return ctx.linear(f"blocks/{bidx}/attn_proj", blk["attn_proj"], y)


def vit_forward(cfg, params, ctx, x):
    """x: [B, T, patch_dim] (pre-patchified by the Rust data pipeline)."""
    h = _dense(params["embed"], x) + params["pos"][None]
    for bi, blk in enumerate(params["blocks"]):
        a = _attention(blk, ctx, bi, _layer_norm(blk["ln1"], h),
                       cfg["heads"], causal=False)
        h = h + a
        m = ctx.linear(f"blocks/{bi}/fc1", blk["fc1"],
                       _layer_norm(blk["ln2"], h))
        m = ctx.linear(f"blocks/{bi}/fc2", blk["fc2"], jax.nn.gelu(m))
        h = h + m
    h = _layer_norm(params["ln_f"], h).mean(axis=1)
    return _dense(params["head"], h)


def mixer_forward(cfg, params, ctx, x):
    h = _dense(params["embed"], x) + params["pos"][None]
    for bi, blk in enumerate(params["blocks"]):
        # token mixing: operate along T
        z = _layer_norm(blk["ln1"], h).transpose(0, 2, 1)     # [B, D, T]
        z = ctx.linear(f"blocks/{bi}/token_fc1", blk["token_fc1"], z)
        z = ctx.linear(f"blocks/{bi}/token_fc2", blk["token_fc2"],
                       jax.nn.gelu(z))
        h = h + z.transpose(0, 2, 1)
        # channel mixing
        z = _layer_norm(blk["ln2"], h)
        z = ctx.linear(f"blocks/{bi}/chan_fc1", blk["chan_fc1"], z)
        z = ctx.linear(f"blocks/{bi}/chan_fc2", blk["chan_fc2"],
                       jax.nn.gelu(z))
        h = h + z
    h = _layer_norm(params["ln_f"], h).mean(axis=1)
    return _dense(params["head"], h)


def gpt_forward(cfg, params, ctx, tokens):
    """tokens: [B, S] int32 → logits [B, S, vocab]."""
    h = params["tok_embed"][tokens] + params["pos"][None, :tokens.shape[1]]
    for bi, blk in enumerate(params["blocks"]):
        a = _attention(blk, ctx, bi, _layer_norm(blk["ln1"], h),
                       cfg["heads"], causal=True)
        h = h + a
        m = ctx.linear(f"blocks/{bi}/fc1", blk["fc1"],
                       _layer_norm(blk["ln2"], h))
        m = ctx.linear(f"blocks/{bi}/fc2", blk["fc2"], jax.nn.gelu(m))
        h = h + m
    h = _layer_norm(params["ln_f"], h)
    return _dense(params["head"], h)


def forward(cfg, params, ctx, x):
    return {"vit": vit_forward, "mixer": mixer_forward,
            "gpt": gpt_forward}[cfg["kind"]](cfg, params, ctx, x)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def ce_loss(logits, labels, smoothing):
    """Mean label-smoothed cross entropy.  logits [..., C], labels [...] i32."""
    c = logits.shape[-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if smoothing > 0.0:
        uniform = -logp.mean(axis=-1)
        nll = (1.0 - smoothing) * nll + smoothing * uniform
    return nll


def classification_loss(cfg, logits, y):
    return ce_loss(logits, y, cfg["smoothing"]).mean()


def lm_loss(cfg, logits, targets):
    return ce_loss(logits, targets, cfg["smoothing"]).mean()
