"""AOT emission: lower every artifact to HLO *text* + write manifest.json.

HLO text (NOT ``lowered.compile().serialize()`` / proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids, which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The text parser on the Rust side reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.

Also emits ``artifacts/golden/*.json`` — small input/output vectors from the
pure-jnp oracles that the Rust crate's unit tests replay against its own
diagonal/BCSR/TopK implementations.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts --set all
"""

import argparse
import json
import os
import time

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from . import artifacts as A
from .kernels import ref

_NP = {"f32": np.float32, "i32": np.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(art):
    args = [jax.ShapeDtypeStruct(tuple(s["shape"]), _NP[s["dtype"]])
            for s in art["inputs"]]
    lowered = jax.jit(art["fn"]).lower(*args)
    return to_hlo_text(lowered)


def emit_golden(out_dir):
    """Oracle IO vectors for Rust-side substrate tests."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(42)

    # diagonal matmul (square + tall + wide)
    cases = []
    for (b, n_in, n_out, k) in [(3, 8, 8, 2), (2, 8, 16, 3), (2, 16, 8, 4)]:
        x = rng.normal(size=(b, n_in)).astype(np.float32)
        offs = rng.choice(n_in, size=k, replace=False).astype(np.int32)
        vals = rng.normal(size=(k, n_out)).astype(np.float32)
        y = np.asarray(ref.diag_matmul_ref(x, offs, vals))
        dy = rng.normal(size=(b, n_out)).astype(np.float32)
        dx = np.asarray(ref.diag_matmul_t_ref(dy, offs, vals, n_in))
        cases.append({
            "b": b, "n_in": n_in, "n_out": n_out, "k": k,
            "x": x.ravel().tolist(), "offsets": offs.tolist(),
            "values": vals.ravel().tolist(), "y": y.ravel().tolist(),
            "dy": dy.ravel().tolist(), "dx": dx.ravel().tolist(),
        })
    with open(os.path.join(gdir, "diag_matmul.json"), "w") as f:
        json.dump({"cases": cases}, f)

    # soft topk
    cases = []
    for d, k, t in [(16, 4.0, 1.0), (32, 3.0, 0.1), (8, 8.0, 5.0)]:
        a = rng.normal(size=(d,)).astype(np.float32)
        out = ref.soft_topk_ref(a, k, t)
        cases.append({"alpha": a.tolist(), "k": k, "t": t,
                      "out": np.asarray(out, np.float64).tolist()})
    with open(os.path.join(gdir, "soft_topk.json"), "w") as f:
        json.dump({"cases": cases}, f)

    # dynadiag weight composition
    n_out, n_in = 6, 8
    v = rng.normal(size=(n_out, n_in)).astype(np.float32)
    at = rng.random(n_in).astype(np.float32)
    w = np.asarray(ref.dynadiag_weight_ref(v, at))
    with open(os.path.join(gdir, "dynadiag_weight.json"), "w") as f:
        json.dump({"n_out": n_out, "n_in": n_in, "v": v.ravel().tolist(),
                   "alpha_tilde": at.tolist(), "w": w.ravel().tolist()}, f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", default="all",
                    choices=["core", "micro", "e2e", "all"])
    ap.add_argument("--only", default=None,
                    help="emit only artifacts whose name contains this")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"artifacts": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    by_name = {a["name"]: a for a in manifest["artifacts"]}

    for make in A.artifact_set(args.set):
        art = make()
        if args.only and args.only not in art["name"]:
            continue
        t0 = time.time()
        text = lower_artifact(art)
        fname = art["name"] + ".hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        by_name[art["name"]] = {
            "name": art["name"],
            "file": fname,
            "inputs": art["inputs"],
            "outputs": art["output_names"],
            "meta": art["meta"],
        }
        print(f"  emitted {art['name']}  ({len(text)/1e6:.1f} MB HLO, "
              f"{time.time()-t0:.1f}s)")

    manifest["artifacts"] = [by_name[k] for k in sorted(by_name.keys())]
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    emit_golden(args.out_dir)
    print(f"wrote {manifest_path} with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
