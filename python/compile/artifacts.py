"""Artifact builders: each returns a traceable fn + a named IO contract.

An *artifact* is one XLA executable the Rust coordinator loads at startup:

  {model}_{param}_train      params+opt+masks?+batch+scalars -> params'+opt'+loss+acc
  {model}_masked_gradprobe   params+masks+batch -> dense grads of sparse layers
  {model}_{param}_eval       params+masks?+batch(+scalars) -> loss, loss_vec, preds
  {model}_diag_infer{S}      diagonal-selected params+batch -> preds (Pallas path)
  micro_*                    single-op kernels for Fig 7 / Table 8 benches

Inputs/outputs are flat, ordered lists of buffers; the names/shapes/dtypes
are recorded in ``manifest.json`` and mirrored by ``rust/src/train/state.rs``.
Section prefixes (``params/``, ``opt_m/``, ``opt_v/``, ``masks/``, ``batch/``,
``scalar/``, ``kvec``) are the routing contract.
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M
from . import optim
from .kernels import diag_matmul, bcsr_matmul


F32 = "f32"
I32 = "i32"
_NP = {F32: np.float32, I32: np.int32}


def spec(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _named_specs(named, prefix=""):
    return [spec(prefix + n, v.shape) for n, v in named]


def _batch_specs(cfg):
    if cfg["kind"] == "gpt":
        return [spec("batch/x", (cfg["batch"], cfg["seq"]), I32),
                spec("batch/y", (cfg["batch"], cfg["seq"]), I32)]
    return [spec("batch/x", (cfg["batch"], cfg["tokens"], cfg["patch_dim"])),
            spec("batch/y", (cfg["batch"],), I32)]


def _accuracy(cfg, logits, y):
    if cfg["kind"] == "gpt":
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


def _loss_of_logits(cfg, logits, y):
    if cfg["kind"] == "gpt":
        return M.lm_loss(cfg, logits, y)
    return M.classification_loss(cfg, logits, y)


def _meta(cfg_name, cfg, kind, param):
    return {
        "model": cfg_name,
        "kind": kind,
        "param": param,
        "config": {k: v for k, v in cfg.items()},
        "sparse_layers": [
            {"name": n, "out": o, "in": i}
            for n, o, i in M.sparse_layer_list(cfg)
        ],
    }


def _get_layer(params, name):
    node = params
    for part in name.split("/"):
        node = node[int(part)] if part.isdigit() else node[part]
    return node


# ---------------------------------------------------------------------------
# Train steps
# ---------------------------------------------------------------------------

def build_train(cfg_name, param_mode):
    cfg = M.CONFIGS[cfg_name]
    params0 = M.init_params(cfg, param_mode)
    named_p = M.flatten_named(params0)
    sparse = M.sparse_layer_list(cfg)
    n_p = len(named_p)

    specs = _named_specs(named_p, "params/")
    specs += _named_specs(named_p, "opt_m/")
    specs += _named_specs(named_p, "opt_v/")
    masks0 = None
    if param_mode == "masked":
        masks0 = {n: np.ones((o, i), np.float32) for n, o, i in sparse}
        named_m = M.flatten_named(masks0)
        specs += _named_specs(named_m, "masks/")
    specs += _batch_specs(cfg)
    specs += [spec("scalar/step", ()), spec("scalar/lr", ()),
              spec("scalar/wd", ())]
    if param_mode == "dynadiag":
        specs += [spec("scalar/temp", ()), spec("scalar/l1", ()),
                  spec("kvec", (len(sparse),))]

    n_masks = len(sparse) if param_mode == "masked" else 0
    n_batch = 2

    def fn(*leaves):
        i = 0
        params = M.unflatten_like(params0, leaves[i:i + n_p]); i += n_p
        m_tree = M.unflatten_like(params0, leaves[i:i + n_p]); i += n_p
        v_tree = M.unflatten_like(params0, leaves[i:i + n_p]); i += n_p
        masks = {}
        if param_mode == "masked":
            masks = M.unflatten_like(masks0, leaves[i:i + n_masks])
            i += n_masks
        x, y = leaves[i], leaves[i + 1]; i += n_batch
        step, lr, wd = leaves[i], leaves[i + 1], leaves[i + 2]; i += 3
        if param_mode == "dynadiag":
            temp, l1c, kvec = leaves[i], leaves[i + 1], leaves[i + 2]

        def loss_fn(p):
            if param_mode == "masked":
                ctx = M.MaskedCtx(masks)
            else:
                ctx = M.DynaDiagCtx([n for n, _, _ in sparse], temp, kvec)
            logits = M.forward(cfg, p, ctx, x)
            loss = _loss_of_logits(cfg, logits, y)
            if param_mode == "dynadiag":
                loss = loss + l1c * ctx.l1
            return loss, _accuracy(cfg, logits, y)

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_p, new_opt = optim.apply(params, grads,
                                     {"m": m_tree, "v": v_tree},
                                     step, lr, wd)
        out = [v for _, v in M.flatten_named(new_p)]
        out += [v for _, v in M.flatten_named(new_opt["m"])]
        out += [v for _, v in M.flatten_named(new_opt["v"])]
        out += [loss, acc]
        return tuple(out)

    out_names = ([f"params/{n}" for n, _ in named_p]
                 + [f"opt_m/{n}" for n, _ in named_p]
                 + [f"opt_v/{n}" for n, _ in named_p]
                 + ["loss", "acc"])
    return {
        "name": f"{cfg_name}_{param_mode}_train",
        "fn": fn,
        "inputs": specs,
        "output_names": out_names,
        "meta": _meta(cfg_name, cfg, "train", param_mode),
    }


def build_gradprobe(cfg_name):
    """Dense grads w.r.t. the *effective* weights of every sparse layer.

    RigL grows the connections with the largest |grad| among *missing*
    weights — that requires d loss / d W_eff, not the masked gradient.
    Called by the coordinator only at topology-update steps.
    """
    cfg = M.CONFIGS[cfg_name]
    params0 = M.init_params(cfg, "masked")
    named_p = M.flatten_named(params0)
    sparse = M.sparse_layer_list(cfg)
    masks0 = {n: np.ones((o, i), np.float32) for n, o, i in sparse}
    named_m = M.flatten_named(masks0)
    n_p, n_m = len(named_p), len(named_m)

    specs = _named_specs(named_p, "params/")
    specs += _named_specs(named_m, "masks/")
    specs += _batch_specs(cfg)

    def fn(*leaves):
        params = M.unflatten_like(params0, leaves[:n_p])
        masks = M.unflatten_like(masks0, leaves[n_p:n_p + n_m])
        x, y = leaves[n_p + n_m], leaves[n_p + n_m + 1]
        weff = {n: _get_layer(params, n)["w"] * masks[n]
                for n, _, _ in sparse}

        def loss_of(weff_):
            ctx = M.MaskedCtx(masks, override=weff_)
            logits = M.forward(cfg, params, ctx, x)
            return _loss_of_logits(cfg, logits, y)

        loss, grads = jax.value_and_grad(loss_of)(weff)
        out = [grads[n] for n in sorted(grads.keys())]
        return tuple(out + [loss])

    out_names = [f"grad/{n}" for n in sorted(masks0.keys())] + ["loss"]
    return {
        "name": f"{cfg_name}_masked_gradprobe",
        "fn": fn,
        "inputs": specs,
        "output_names": out_names,
        "meta": _meta(cfg_name, cfg, "gradprobe", "masked"),
    }


# ---------------------------------------------------------------------------
# Eval
# ---------------------------------------------------------------------------

def build_eval(cfg_name, param_mode):
    cfg = M.CONFIGS[cfg_name]
    params0 = M.init_params(cfg, param_mode)
    named_p = M.flatten_named(params0)
    sparse = M.sparse_layer_list(cfg)
    n_p = len(named_p)

    specs = _named_specs(named_p, "params/")
    masks0 = None
    if param_mode == "masked":
        masks0 = {n: np.ones((o, i), np.float32) for n, o, i in sparse}
        specs += _named_specs(M.flatten_named(masks0), "masks/")
    specs += _batch_specs(cfg)
    if param_mode == "dynadiag":
        specs += [spec("scalar/temp", ()), spec("kvec", (len(sparse),))]
    n_masks = len(sparse) if param_mode == "masked" else 0

    def fn(*leaves):
        i = 0
        params = M.unflatten_like(params0, leaves[i:i + n_p]); i += n_p
        masks = {}
        if param_mode == "masked":
            masks = M.unflatten_like(masks0, leaves[i:i + n_masks])
            i += n_masks
        x, y = leaves[i], leaves[i + 1]; i += 2
        if param_mode == "dynadiag":
            temp, kvec = leaves[i], leaves[i + 1]
            ctx = M.DynaDiagCtx([n for n, _, _ in sparse], temp, kvec)
        else:
            ctx = M.MaskedCtx(masks)
        logits = M.forward(cfg, params, ctx, x)
        if cfg["kind"] == "gpt":
            per_tok = M.ce_loss(logits, y, 0.0)                # [B, S]
            loss_vec = per_tok.mean(axis=-1)                   # [B]
            correct = jnp.sum((jnp.argmax(logits, -1) == y)
                              .astype(jnp.int32), axis=-1)     # [B]
            return loss_vec.mean(), loss_vec, correct
        per_ex = M.ce_loss(logits, y, 0.0)                     # [B]
        preds = jnp.argmax(logits, -1).astype(jnp.int32)       # [B]
        return per_ex.mean(), per_ex, preds

    out_names = ["loss", "loss_vec",
                 "correct" if cfg["kind"] == "gpt" else "preds"]
    return {
        "name": f"{cfg_name}_{param_mode}_eval",
        "fn": fn,
        "inputs": specs,
        "output_names": out_names,
        "meta": _meta(cfg_name, cfg, "eval", param_mode),
    }


# ---------------------------------------------------------------------------
# Diagonal-selected inference (the L1 Pallas execution path)
# ---------------------------------------------------------------------------

def diag_k(n_in, sparsity):
    return max(1, int(round((1.0 - sparsity) * n_in)))


def build_diag_infer(cfg_name, sparsity):
    """Inference where each sparse layer runs kernels.diag_matmul over its
    selected K diagonals (offsets+values inputs, K static per sparsity)."""
    cfg = M.CONFIGS[cfg_name]
    sparse = M.sparse_layer_list(cfg)
    sparse_names = {n for n, _, _ in sparse}
    params0 = M.init_params(cfg, "masked")

    # swap sparse layers' {"w"} for {"offsets","values"} in the template
    def swap(node, prefix=""):
        if isinstance(node, dict):
            return {k: swap(v, f"{prefix}{k}/") for k, v in node.items()}
        if isinstance(node, list):
            return [swap(v, f"{prefix}{i}/") for i, v in enumerate(node)]
        return node

    params0 = swap(params0)
    for n, o, i in sparse:
        layer = _get_layer(params0, n)
        k = diag_k(i, sparsity)
        del layer["w"]
        layer["offsets"] = np.zeros((k,), np.int32)
        layer["values"] = np.zeros((k, o), np.float32)

    named_p = M.flatten_named(params0)
    n_p = len(named_p)
    specs = [spec("params/" + n, v.shape,
                  I32 if v.dtype == np.int32 else F32) for n, v in named_p]
    specs += _batch_specs(cfg)

    def fn(*leaves):
        params = M.unflatten_like(params0, leaves[:n_p])
        x, y = leaves[n_p], leaves[n_p + 1]
        ctx = M.DiagExecCtx(sparse_names)
        logits = M.forward(cfg, params, ctx, x)
        if cfg["kind"] == "gpt":
            loss = M.ce_loss(logits, y, 0.0).mean()
            correct = jnp.sum((jnp.argmax(logits, -1) == y)
                              .astype(jnp.int32), axis=-1)
            return loss, correct
        loss = M.ce_loss(logits, y, 0.0).mean()
        preds = jnp.argmax(logits, -1).astype(jnp.int32)
        return loss, preds

    out_names = ["loss", "correct" if cfg["kind"] == "gpt" else "preds"]
    pct = int(round(sparsity * 100))
    meta = _meta(cfg_name, cfg, "diag_infer", "diag")
    meta["sparsity"] = sparsity
    meta["diag_k"] = {n: diag_k(i, sparsity) for n, _, i in sparse}
    return {
        "name": f"{cfg_name}_diag_infer{pct}",
        "fn": fn,
        "inputs": specs,
        "output_names": out_names,
        "meta": meta,
    }


# ---------------------------------------------------------------------------
# Micro-kernels (Fig 7 / Table 8 benches, kernel-level timing)
# ---------------------------------------------------------------------------

def build_micro_diag(n, k, batch=64):
    """Single diag_matmul over an n×n matrix with K diagonals."""
    specs = [spec("x", (batch, n)), spec("offsets", (k,), I32),
             spec("values", (k, n))]

    def fn(x, offsets, values):
        return (diag_matmul(x, offsets, values),)

    return {
        "name": f"micro_diag_n{n}_k{k}",
        "fn": fn,
        "inputs": specs,
        "output_names": ["y"],
        "meta": {"kind": "micro_diag", "n": n, "k": k, "batch": batch},
    }


def build_micro_dense(n, batch=64):
    specs = [spec("x", (batch, n)), spec("w", (n, n))]

    def fn(x, w):
        return (x @ w.T,)

    return {
        "name": f"micro_dense_n{n}",
        "fn": fn,
        "inputs": specs,
        "output_names": ["y"],
        "meta": {"kind": "micro_dense", "n": n, "batch": batch},
    }


def build_micro_bcsr(n, nnzb, bs, batch=64):
    nbr = n // bs
    specs = [spec("x", (batch, n)), spec("row_ptr", (nbr + 1,), I32),
             spec("col_idx", (nnzb,), I32), spec("blocks", (nnzb, bs, bs))]

    def fn(x, row_ptr, col_idx, blocks):
        return (bcsr_matmul(x, row_ptr, col_idx, blocks, n),)

    return {
        "name": f"micro_bcsr_n{n}_nnzb{nnzb}_bs{bs}",
        "fn": fn,
        "inputs": specs,
        "output_names": ["y"],
        "meta": {"kind": "micro_bcsr", "n": n, "nnzb": nnzb, "bs": bs,
                 "batch": batch},
    }


# ---------------------------------------------------------------------------
# Artifact sets
# ---------------------------------------------------------------------------

CORE_MODELS = ["vit_micro", "mixer_micro", "vit_tiny", "mixer_tiny",
               "gpt_mini"]
FIG7_N = 768
FIG7_SPARSITIES = [0.99, 0.95, 0.90, 0.80, 0.70, 0.60, 0.50, 0.20]


def artifact_set(which):
    builders = []
    if which in ("core", "all"):
        for m in CORE_MODELS:
            builders.append(lambda m=m: build_train(m, "masked"))
            builders.append(lambda m=m: build_train(m, "dynadiag"))
            builders.append(lambda m=m: build_gradprobe(m))
            builders.append(lambda m=m: build_eval(m, "masked"))
            builders.append(lambda m=m: build_eval(m, "dynadiag"))
        for m in ["vit_tiny", "mixer_tiny", "gpt_mini"]:
            builders.append(lambda m=m: build_diag_infer(m, 0.9))
    if which in ("micro", "all"):
        for s in FIG7_SPARSITIES:
            k = diag_k(FIG7_N, s)
            builders.append(lambda k=k: build_micro_diag(FIG7_N, k))
        builders.append(lambda: build_micro_dense(FIG7_N))
        builders.append(lambda: build_micro_bcsr(
            FIG7_N, nnzb=2 * diag_k(FIG7_N, 0.9) * (FIG7_N // 16), bs=16))
    if which in ("e2e", "all"):
        builders.append(lambda: build_train("gpt_e2e", "dynadiag"))
        builders.append(lambda: build_eval("gpt_e2e", "dynadiag"))
    return builders
