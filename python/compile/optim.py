"""In-graph AdamW — the whole update rule compiles into the train-step HLO.

The Rust coordinator owns the *schedule* (learning rate, weight decay,
temperature); this module owns the *update math*.  lr/wd/step arrive as
runtime scalars so one artifact serves any schedule.

Weight decay is decoupled (AdamW) and applied only to matrix-shaped
parameters (ndim >= 2) whose path does not mark them as exempt — DynaDiag's
``alpha`` vectors are regularized by the in-graph L1 term instead (Sec 3.2),
and biases / layernorm scales are never decayed, matching the paper's
training recipes (Apdx C).
"""

import jax
import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def init_state(params):
    """Zeroed first/second moment trees mirroring ``params``."""
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params)}


def _decay_this(path, leaf):
    """AdamW decay mask: 2-D+ weights only, never alpha vectors."""
    name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    if name.endswith("alpha"):
        return False
    return leaf.ndim >= 2


def apply(params, grads, opt, step, lr, wd):
    """One AdamW step.

    Args:
      params, grads: matching pytrees.
      opt: {"m": tree, "v": tree} from :func:`init_state`.
      step: scalar f32, 1-based step count (bias correction).
      lr, wd: scalar f32 runtime inputs.

    Returns:
      (new_params, new_opt)
    """
    b1c = 1.0 - BETA1 ** step
    b2c = 1.0 - BETA2 ** step

    def upd(path, p, g, m, v):
        m = BETA1 * m + (1.0 - BETA1) * g
        vv = BETA2 * v + (1.0 - BETA2) * (g * g)
        mh = m / b1c
        vh = vv / b2c
        new_p = p - lr * mh / (jnp.sqrt(vh) + EPS)
        if _decay_this(path, p):
            new_p = new_p - lr * wd * p
        return new_p, m, vv

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt["m"])
    flat_v = jax.tree_util.tree_leaves(opt["v"])
    out_p, out_m, out_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(path, p, g, m, v)
        out_p.append(np_)
        out_m.append(nm)
        out_v.append(nv)
    unflat = lambda leaves: jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), leaves)
    return unflat(out_p), {"m": unflat(out_m), "v": unflat(out_v)}
