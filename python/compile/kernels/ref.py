"""Pure-jnp oracles for every kernel in this package.

These are the CORE correctness signal: pytest checks each Pallas kernel
against the function here on swept shapes/dtypes (see
``python/tests/test_kernels.py``), and the Rust side re-checks its own BCSR /
diagonal implementations against numbers produced by these oracles (golden
vectors shipped in ``artifacts/golden/``).

Conventions (shared with the Rust crate — see ``rust/src/sparsity/diagonal.rs``):

  * A linear layer computes ``y = x @ W.T + b`` with ``W in R^{n_out x n_in}``.
  * Candidate diagonal offsets are ``off in {0, .., n_in-1}``.  Diagonal
    ``off`` owns exactly the entries ``(i, (i + off) mod n_in)`` for
    ``i in 0..n_out`` — every element of W belongs to exactly one diagonal
    (``off = (j - i) mod n_in``), so K selected diagonals give density
    ``K / n_in``.
  * ``values`` are stored offset-major: ``values[j, i]`` is the entry of
    diagonal ``offsets[j]`` at row ``i``.
"""

import jax.numpy as jnp
import numpy as np


def alpha_index_matrix(n_out, n_in):
    """IDX[i, j] = (j - i) mod n_in — which candidate diagonal owns (i, j)."""
    i = np.arange(n_out)[:, None]
    j = np.arange(n_in)[None, :]
    return ((j - i) % n_in).astype(np.int32)


def compose_dense(offsets, values, n_out, n_in):
    """Materialize the diagonal-sparse W from (offsets, values).

    W[i, (i + off_j) mod n_in] = values[j, i].
    """
    offsets = np.asarray(offsets)
    values = np.asarray(values)
    w = np.zeros((n_out, n_in), dtype=values.dtype)
    rows = np.arange(n_out)
    for j, off in enumerate(offsets):
        cols = (rows + int(off)) % n_in
        w[rows, cols] = values[j]
    return jnp.asarray(w)


def diag_matmul_ref(x, offsets, values):
    """Oracle for the forward diagonal-sparse product ``y = x @ W.T``.

    x: [B, n_in]; offsets: [K] int32; values: [K, n_out].  Returns [B, n_out].
    """
    n_in = x.shape[-1]
    n_out = values.shape[-1]
    w = compose_dense(offsets, values, n_out, n_in)
    return x @ w.T


def diag_matmul_t_ref(dy, offsets, values, n_in):
    """Oracle for the transposed product ``dx = dy @ W``.

    dy: [B, n_out]; returns [B, n_in].  This is the backward-pass product the
    paper accelerates by Apdx-A transposition invariance.
    """
    n_out = dy.shape[-1]
    w = compose_dense(offsets, values, n_out, n_in)
    return dy @ w


def dynadiag_weight_ref(v_dense, alpha_tilde):
    """W = V ⊙ alpha_tilde[(j - i) mod n_in]  (Eq. 4, dense-sim form).

    v_dense: [n_out, n_in] all candidate diagonal values in matrix position.
    alpha_tilde: [n_in] soft-TopK weights.
    """
    n_out, n_in = v_dense.shape
    idx = jnp.asarray(alpha_index_matrix(n_out, n_in))
    return v_dense * alpha_tilde[idx]


# ---------------------------------------------------------------------------
# BCSR
# ---------------------------------------------------------------------------

def bcsr_to_dense(row_ptr, col_idx, blocks, n_out, n_in):
    """Expand a BCSR matrix to dense.

    row_ptr: [n_block_rows + 1] int32;  col_idx: [nnzb] int32 (block cols);
    blocks: [nnzb, bs_r, bs_c].
    """
    row_ptr = np.asarray(row_ptr)
    col_idx = np.asarray(col_idx)
    blocks = np.asarray(blocks)
    nnzb, bs_r, bs_c = blocks.shape
    w = np.zeros((n_out, n_in), dtype=blocks.dtype)
    n_block_rows = len(row_ptr) - 1
    for br in range(n_block_rows):
        for p in range(int(row_ptr[br]), int(row_ptr[br + 1])):
            bc = int(col_idx[p])
            w[br * bs_r:(br + 1) * bs_r, bc * bs_c:(bc + 1) * bs_c] = blocks[p]
    return jnp.asarray(w)


def bcsr_matmul_ref(x, row_ptr, col_idx, blocks, n_out):
    """Oracle for ``y = x @ W.T`` with W in BCSR form.  x: [B, n_in]."""
    n_in = x.shape[-1]
    w = bcsr_to_dense(row_ptr, col_idx, blocks, n_out, n_in)
    return x @ w.T


def soft_topk_ref(alpha, k, temperature):
    """NumPy oracle for kernels.topk.soft_topk."""
    alpha = np.asarray(alpha, dtype=np.float64)
    t = max(float(temperature), 1e-6)
    z = alpha / t
    z = z - z.max()
    p = np.exp(z) / np.exp(z).sum()
    return np.minimum(float(k) * p, 1.0)
