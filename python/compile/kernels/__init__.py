"""L1 Pallas kernels for DynaDiag (interpret=True on CPU; see DESIGN.md §7).

Exports:
  diag_matmul / diag_matmul_t — diagonal-sparse products (fwd / transposed)
  bcsr_matmul                 — block-sparse product over BCSR
  soft_topk / hard_topk_mask  — Eq. 5 TopK
  ref                         — pure-jnp oracles for all of the above
"""

from . import ref  # noqa: F401
from .bcsr_matmul import bcsr_matmul  # noqa: F401
from .diag_matmul import diag_matmul, diag_matmul_t  # noqa: F401
from .topk import hard_topk_mask, soft_topk, straight_through_topk  # noqa: F401
