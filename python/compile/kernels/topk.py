"""Differentiable soft-TopK (Eq. 5 of the paper) and hard TopK helpers.

The paper selects the K most important diagonals via a temperature-controlled
softmax TopK:

    alpha_tilde_i = min(k * softmax(alpha / T)_i, 1)

A high temperature T spreads mass over many diagonals (exploration); low T
concentrates it on the top K (exploitation).  T is annealed by the Rust
coordinator (cosine by default, Table 15 / Fig 8 ablate this) and enters the
compiled graph as a runtime scalar, so a single artifact serves the whole
schedule.  ``k`` is likewise a runtime scalar so one artifact serves every
sparsity level.
"""

import jax
import jax.numpy as jnp


def _softmax(x):
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def soft_topk(alpha, k, temperature):
    """Soft TopK weights, Eq. 5.

    Args:
      alpha: [D] importance logits (one per candidate diagonal).
      k: scalar (float) — number of diagonals the budget allows.
      temperature: scalar (float) — softmax temperature T.

    Returns:
      [D] weights in [0, 1]; approximately K entries near 1 as T -> 0.
    """
    t = jnp.maximum(temperature, 1e-6)
    return jnp.minimum(k * _softmax(alpha / t), 1.0)


def hard_topk_mask(alpha, k):
    """Binary indicator of the top-k entries of ``alpha`` (k static int).

    Used at finalization time (and in tests) — the Rust coordinator performs
    the equivalent selection on the host when extracting the final diagonal
    set.
    """
    d = alpha.shape[-1]
    k = int(k)
    if k >= d:
        return jnp.ones_like(alpha)
    thresh = jnp.sort(alpha)[..., d - k]
    return (alpha >= thresh).astype(alpha.dtype)


def straight_through_topk(alpha, k, temperature):
    """Hard TopK forward, soft-TopK gradients (straight-through estimator).

    Not used by the default DynaDiag pipeline (the paper trains with the
    soft weights); exposed for the estimator ablation in EXPERIMENTS.md.
    ``k`` must be a static int here because the hard mask needs a sort cut.
    """
    soft = soft_topk(alpha, float(k), temperature)
    hard = hard_topk_mask(alpha, int(k))
    return soft + jax.lax.stop_gradient(hard - soft)
