"""Pallas BCSR (Block Compressed Sparse Row) matmul kernel.

The paper's GPU execution path converts selected diagonals to BCSR
(Sec 3.3 / Apdx D) and runs an SmaT-style tensor-core kernel over the
non-zero blocks.  The TPU mapping (DESIGN.md §7):

  * each grid step owns one *block row* of W — the analogue of a CUDA
    threadblock owning a row-panel of C;
  * ``rowPtr``/``colIdx`` iteration happens inside the kernel with
    ``lax.fori_loop`` over exactly the non-zero blocks (no work on zeros);
  * each non-zero block is a (bs_r, bs_c) dense tile — shaped for the MXU
    the way SmaT shapes them for mma.m16n8k16; the x panel it touches is
    sliced out of a VMEM-resident activation slab.

Blocks are padded to a static ``nnzb`` by the Rust converter so one compiled
artifact serves every topology at a given sparsity (padding blocks carry
col 0 and all-zero values — they are harmless adds).

Shapes:
  x:       [B, n_in]
  row_ptr: [n_out/bs_r + 1] int32
  col_idx: [nnzb] int32 (block-column indices)
  blocks:  [nnzb, bs_r, bs_c]
  y:       [B, n_out] = x @ W.T
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bcsr_kernel(row_ptr_ref, col_idx_ref, x_ref, blocks_ref, o_ref, *, bs_c):
    """One grid step = one block row of W accumulated into a [B, bs_r] tile."""
    br = pl.program_id(0)
    x = x_ref[...]                       # [B, n_in] resident slab
    blocks = blocks_ref[...]             # [nnzb, bs_r, bs_c] resident
    col_idx = col_idx_ref[...]
    start = row_ptr_ref[br]
    stop = row_ptr_ref[br + 1]
    b = x.shape[0]
    bs_r = blocks.shape[1]

    def body(p, acc):
        bc = col_idx[p]
        xp = jax.lax.dynamic_slice(x, (0, bc * bs_c), (b, bs_c))   # [B, bs_c]
        blk = jax.lax.dynamic_index_in_dim(blocks, p, axis=0,
                                           keepdims=False)         # [bs_r, bs_c]
        return acc + xp @ blk.T

    acc0 = jnp.zeros((b, bs_r), dtype=x.dtype)
    o_ref[...] = jax.lax.fori_loop(start, stop, body, acc0)


def bcsr_matmul(x, row_ptr, col_idx, blocks, n_out, *, interpret=True):
    """Block-sparse product ``y = x @ W.T`` over non-zero blocks only."""
    b, n_in = x.shape
    nnzb, bs_r, bs_c = blocks.shape
    n_block_rows = n_out // bs_r
    assert n_out % bs_r == 0 and n_in % bs_c == 0
    assert row_ptr.shape == (n_block_rows + 1,)
    kernel = functools.partial(_bcsr_kernel, bs_c=bs_c)
    return pl.pallas_call(
        kernel,
        grid=(n_block_rows,),
        in_specs=[
            pl.BlockSpec((n_block_rows + 1,), lambda br: (0,)),     # row_ptr
            pl.BlockSpec((nnzb,), lambda br: (0,)),                 # col_idx
            pl.BlockSpec((b, n_in), lambda br: (0, 0)),             # x slab
            pl.BlockSpec((nnzb, bs_r, bs_c), lambda br: (0, 0, 0)),  # blocks
        ],
        out_specs=pl.BlockSpec((b, bs_r), lambda br: (0, br)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), x.dtype),
        interpret=interpret,
    )(row_ptr, col_idx, x, blocks)
