"""Pallas kernels for diagonal-sparse matrix products (the paper's L1 hot-spot).

The paper accelerates diagonally sparse weight matrices on GPUs with custom
CUDA kernels over a BCSR conversion (Apdx D).  On TPU the same insight —
*a diagonal is a unit-stride object you can stream, not a random scatter* —
maps differently (DESIGN.md §7 Hardware-Adaptation):

  * instead of warps owning m16n8k16 output tiles, each grid step owns one
    selected diagonal and a VMEM-resident tile of the output;
  * the mod-wrap gather ``x[:, (i + off) mod n_in]`` is realized with
    ``jnp.roll`` on a VMEM-resident slab — a pair of contiguous copies, the
    TPU analogue of the CUDA kernel's coalesced per-diagonal loads (no random
    access is ever issued);
  * accumulation happens in the output VMEM tile across the K grid steps
    (sequential grid on TPU ⇒ safe read-modify-write).

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU utilization is estimated in DESIGN.md from the
BlockSpec footprints.

Shapes (see ref.py for conventions):
  x:        [B, n_in]   activations
  offsets:  [K]  int32  selected diagonal offsets, 0 <= off < n_in
  values:   [K, n_out]  diagonal entries, offset-major (already α-scaled)
  y:        [B, n_out]  ``y = x @ W.T`` with W the composed diagonal matrix
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(off_ref, x_ref, v_ref, o_ref, *, n_in, n_out):
    """One grid step = one selected diagonal j accumulated into the output.

    y[b, i] += v[j, i] * x[b, (i + off_j) mod n_in]
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    off = off_ref[0]
    x = x_ref[...]                      # [B, n_in] (VMEM-resident slab)
    # Gathered operand: g[b, i] = x[b, (i + off) mod n_in] for i < n_out.
    # roll by -off makes column i hold x[:, (i + off) mod n_in]; when
    # n_out > n_in the diagonal wraps rows, so tile the rolled slab.
    rolled = jnp.roll(x, -off, axis=1)  # two contiguous copies, no gather
    if n_out <= n_in:
        g = rolled[:, :n_out]
    else:
        reps = -(-n_out // n_in)        # ceil
        g = jnp.tile(rolled, (1, reps))[:, :n_out]
    o_ref[...] += g * v_ref[0, :][None, :]


def diag_matmul(x, offsets, values, *, interpret=True):
    """Diagonal-sparse forward product ``y = x @ W.T`` (Fig 3d/e).

    Compiled with a grid over the K selected diagonals; x and the output
    stay VMEM-resident while one (1, n_out) values row streams in per step.
    """
    b, n_in = x.shape
    k, n_out = values.shape
    assert offsets.shape == (k,)
    kernel = functools.partial(_fwd_kernel, n_in=n_in, n_out=n_out)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1,), lambda j: (j,)),            # offsets[j]
            pl.BlockSpec((b, n_in), lambda j: (0, 0)),      # x (resident)
            pl.BlockSpec((1, n_out), lambda j: (j, 0)),     # values row j
        ],
        out_specs=pl.BlockSpec((b, n_out), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), x.dtype),
        interpret=interpret,
    )(offsets, x, values)


def _t_kernel(off_ref, dy_ref, v_ref, o_ref, *, n_in, n_out):
    """Transposed product step: dx[b, (i + off) mod n_in] += v[j, i] dy[b, i].

    Realized scatter-free by the Apdx-A invariance: the transpose of a
    pseudo-diagonal is a pseudo-diagonal, so the scatter into dx is the roll
    of a contiguous product.  dx[b, c] = sum_{i ≡ c-off (mod n_in)} v[j,i]·dy[b,i].
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    off = off_ref[0]
    prod = dy_ref[...] * v_ref[0, :][None, :]   # [B, n_out]
    b = prod.shape[0]
    if n_out >= n_in:
        # fold wrapped row segments back onto n_in columns, then roll by +off
        reps = -(-n_out // n_in)
        pad = reps * n_in - n_out
        padded = jnp.pad(prod, ((0, 0), (0, pad)))
        folded = padded.reshape(b, reps, n_in).sum(axis=1)
    else:
        folded = jnp.pad(prod, ((0, 0), (0, n_in - n_out)))
    o_ref[...] += jnp.roll(folded, off, axis=1)


def diag_matmul_t(dy, offsets, values, n_in, *, interpret=True):
    """Transposed diagonal-sparse product ``dx = dy @ W`` (Fig 3g/h/i).

    Same diagonal set serves forward and backward (Apdx A) — this is the
    property that lets DynaDiag keep the *training* pass sparse where N:M
    methods fall back to dense.
    """
    b, n_out = dy.shape
    k, n_out2 = values.shape
    assert n_out2 == n_out and offsets.shape == (k,)
    kernel = functools.partial(_t_kernel, n_in=n_in, n_out=n_out)
    return pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1,), lambda j: (j,)),
            pl.BlockSpec((b, n_out), lambda j: (0, 0)),
            pl.BlockSpec((1, n_out), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, n_in), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_in), dy.dtype),
        interpret=interpret,
    )(offsets, dy, values)
